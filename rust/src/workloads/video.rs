//! Video/image latent workloads: T×H×W token grids with spatial
//! correlation — the proxy for CogvideoX / Mochi / Open-Sora-Plan /
//! Flux / SD3.5 attention inputs (DESIGN.md §3 substitution table).
//!
//! Correlation is generated over the *3-D grid* (separable AR(1) smoothing
//! along T, H, W), so locality follows spatial adjacency rather than flat
//! token order. That is exactly the structure the HilbertCurve permutation
//! exploits (§3.7): a row-major flattening breaks H/T adjacency while the
//! Hilbert order preserves it.

use crate::sparge::hilbert::{permute_rows, token_order, Permutation};
use crate::tensor::Tensor;
use crate::util::rng::Pcg;

use super::synthetic::QkvSample;

/// Specification for a correlated video-grid workload.
#[derive(Clone, Copy, Debug)]
pub struct VideoSpec {
    pub t: usize,
    pub h: usize,
    pub w: usize,
    pub d: usize,
    /// Spatial smoothing strength ∈ [0,1): higher = smoother latents.
    pub smooth: f32,
    /// Directional signal scale vs iid noise.
    pub signal: f32,
}

impl VideoSpec {
    pub fn tokens(&self) -> usize {
        self.t * self.h * self.w
    }

    /// Mochi-proxy: longer clips, moderate resolution (≈22K tokens scaled
    /// down by `scale` to keep CPU runs tractable).
    pub fn mochi_proxy(scale: usize) -> VideoSpec {
        VideoSpec { t: (28 / scale.max(1)).max(1), h: 30, w: 26, d: 64, smooth: 0.96, signal: 11.0 }
    }

    /// CogvideoX-proxy (≈17K tokens full scale).
    pub fn cogvideo_proxy(scale: usize) -> VideoSpec {
        VideoSpec { t: (24 / scale.max(1)).max(1), h: 27, w: 26, d: 64, smooth: 0.95, signal: 10.0 }
    }

    /// Image (Flux/SD3.5) proxy: single frame, ≈4.5K tokens.
    pub fn image_proxy() -> VideoSpec {
        VideoSpec { t: 1, h: 68, w: 66, d: 64, smooth: 0.94, signal: 10.0 }
    }
}

/// Generate one attention head over the grid in **row-major token order**
/// (T, then H, then W). Apply [`permute`] to re-order.
///
/// Q and K are both derived from one shared *content* field (plus small
/// independent components): that is what makes attention spatially local —
/// a query matches keys whose content correlates with its own, and content
/// correlates over the grid. Independent Q/K fields would give high block
/// self-similarity but a position-free attention map with no exploitable
/// sparsity.
pub fn generate_grid(spec: &VideoSpec, rng: &mut Pcg) -> QkvSample {
    let n = spec.tokens();
    let d = spec.d;
    let content = smooth_field(spec, rng);
    let q_own = smooth_field(spec, rng);
    let k_own = smooth_field(spec, rng);
    let mut q = Tensor::zeros(&[n, d]);
    let mut k = Tensor::zeros(&[n, d]);
    // noise sized vs the (unit-norm) signal rows — see synthetic.rs
    let noise = 0.4 * spec.signal / (d as f32).sqrt();
    let mix = 0.45; // weight of the head-specific component vs shared content
    for i in 0..n {
        for c in 0..d {
            let qdir = content.at2(i, c) + mix * q_own.at2(i, c);
            let kdir = content.at2(i, c) + mix * k_own.at2(i, c);
            *q.at2_mut(i, c) = spec.signal * qdir + rng.gauss() * noise;
            *k.at2_mut(i, c) = spec.signal * kdir + rng.gauss() * noise;
        }
    }
    QkvSample { q, k, v: Tensor::randn(&[n, d], rng) }
}

/// Smooth latent field: iid Gaussians smoothed separably along W, H, T
/// with AR coefficient `smooth`, then row-normalized to ~unit directions.
fn smooth_field(spec: &VideoSpec, rng: &mut Pcg) -> Tensor {
    let (t, h, w, d) = (spec.t, spec.h, spec.w, spec.d);
    let n = t * h * w;
    let mut f = Tensor::randn(&[n, d], rng);
    let rho = spec.smooth.clamp(0.0, 0.999);
    // variance-preserving innovation: keeps correlation *local* (length
    // ≈ 1/(1−ρ)) instead of collapsing the whole field to one direction.
    let nu = (1.0 - rho * rho).sqrt();
    let lin = |tt: usize, hh: usize, ww: usize| (tt * h + hh) * w + ww;

    // forward AR pass along each axis (in-place, per channel)
    for tt in 0..t {
        for hh in 0..h {
            for ww in 1..w {
                let (prev, cur) = (lin(tt, hh, ww - 1), lin(tt, hh, ww));
                for c in 0..d {
                    let pv = f.at2(prev, c);
                    let cv = f.at2(cur, c);
                    *f.at2_mut(cur, c) = rho * pv + nu * cv;
                }
            }
        }
    }
    for tt in 0..t {
        for ww in 0..w {
            for hh in 1..h {
                let (prev, cur) = (lin(tt, hh - 1, ww), lin(tt, hh, ww));
                for c in 0..d {
                    let pv = f.at2(prev, c);
                    let cv = f.at2(cur, c);
                    *f.at2_mut(cur, c) = rho * pv + nu * cv;
                }
            }
        }
    }
    for hh in 0..h {
        for ww in 0..w {
            for tt in 1..t {
                let (prev, cur) = (lin(tt - 1, hh, ww), lin(tt, hh, ww));
                for c in 0..d {
                    let pv = f.at2(prev, c);
                    let cv = f.at2(cur, c);
                    *f.at2_mut(cur, c) = rho * pv + nu * cv;
                }
            }
        }
    }
    // normalize rows to unit directions
    for i in 0..n {
        let nm = crate::tensor::ops::norm(f.row(i));
        if nm > 0.0 {
            for v in f.row_mut(i) {
                *v /= nm;
            }
        }
    }
    f
}

/// Re-order a grid sample's tokens by a permutation method.
pub fn permute(sample: &QkvSample, spec: &VideoSpec, perm: Permutation, seed: u64) -> QkvSample {
    let order = token_order(perm, spec.t, spec.h, spec.w, seed);
    QkvSample {
        q: permute_rows(&sample.q, &order),
        k: permute_rows(&sample.k, &order),
        v: permute_rows(&sample.v, &order),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparge::metrics::avg_block_similarity;

    fn small_spec() -> VideoSpec {
        VideoSpec { t: 4, h: 12, w: 12, d: 16, smooth: 0.93, signal: 4.0 }
    }

    #[test]
    fn grid_shapes() {
        let spec = small_spec();
        let mut rng = Pcg::seeded(1);
        let s = generate_grid(&spec, &mut rng);
        assert_eq!(s.q.shape(), &[spec.tokens(), spec.d]);
    }

    #[test]
    fn hilbert_beats_random_similarity() {
        let spec = small_spec();
        let mut rng = Pcg::seeded(2);
        let s = generate_grid(&spec, &mut rng);
        let hil = permute(&s, &spec, Permutation::HilbertCurve, 0);
        let rnd = permute(&s, &spec, Permutation::Random, 0);
        let sim_h = avg_block_similarity(&hil.k, 64);
        let sim_r = avg_block_similarity(&rnd.k, 64);
        assert!(sim_h > sim_r + 0.05, "hilbert {sim_h} vs random {sim_r}");
    }

    #[test]
    fn hilbert_at_least_matches_rowmajor_similarity() {
        let spec = small_spec();
        let mut rng = Pcg::seeded(3);
        let s = generate_grid(&spec, &mut rng);
        let hil = permute(&s, &spec, Permutation::HilbertCurve, 0);
        let row = permute(&s, &spec, Permutation::RowMajor, 0);
        let sim_h = avg_block_similarity(&hil.k, 64) + avg_block_similarity(&hil.q, 64);
        let sim_r = avg_block_similarity(&row.k, 64) + avg_block_similarity(&row.q, 64);
        assert!(sim_h > sim_r - 0.02, "hilbert {sim_h} vs rowmajor {sim_r}");
    }

    #[test]
    fn permutation_preserves_token_multiset() {
        let spec = small_spec();
        let mut rng = Pcg::seeded(4);
        let s = generate_grid(&spec, &mut rng);
        let p = permute(&s, &spec, Permutation::HilbertCurve, 0);
        let mut a: Vec<u32> = s.q.data().iter().map(|f| f.to_bits()).collect();
        let mut b: Vec<u32> = p.q.data().iter().map(|f| f.to_bits()).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn proxies_have_expected_scale() {
        assert!((VideoSpec::mochi_proxy(1).tokens() as i64 - 22_000).abs() < 2_000);
        assert!((VideoSpec::cogvideo_proxy(1).tokens() as i64 - 17_000).abs() < 2_000);
        assert!((VideoSpec::image_proxy().tokens() as i64 - 4_500).abs() < 200);
    }
}
