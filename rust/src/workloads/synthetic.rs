//! Synthetic attention-input generators with controllable structure.
//!
//! The paper's key empirical observation (Fig. 4) is that Q/K of real
//! models show strong *local* similarity: neighbouring tokens point in
//! similar directions, with occasional global features (sinks, spikes).
//! These generators reproduce that statistic with tunable knobs so every
//! experiment can sweep from "random" (no structure, ≈0 sparsity
//! available) to "strongly local" (high sparsity available).

use crate::tensor::Tensor;
use crate::util::rng::Pcg;

/// A single-head attention problem.
#[derive(Clone, Debug)]
pub struct QkvSample {
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
}

/// Knobs for the correlated generator.
#[derive(Clone, Copy, Debug)]
pub struct SyntheticSpec {
    /// Sequence length.
    pub n: usize,
    /// Head dimension.
    pub d: usize,
    /// Random-walk correlation ∈ [0,1): 0 = iid tokens, →1 = slowly-varying
    /// token directions (high block self-similarity).
    pub locality: f32,
    /// Scale of the shared directional component vs iid noise.
    pub signal: f32,
    /// Number of "sink" tokens at the start of K with boosted norm
    /// (language-model attention-sink artefact).
    pub sinks: usize,
    /// Fraction of heavy-hitter keys scattered through the sequence.
    pub heavy_frac: f32,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec { n: 1024, d: 64, locality: 0.995, signal: 5.0, sinks: 4, heavy_frac: 0.0 }
    }
}

impl SyntheticSpec {
    pub fn random(n: usize, d: usize) -> Self {
        SyntheticSpec { n, d, locality: 0.0, signal: 0.0, sinks: 0, heavy_frac: 0.0 }
    }

    /// Language-model-like: local + sinks + a few heavy hitters.
    pub fn lm_like(n: usize, d: usize) -> Self {
        SyntheticSpec { n, d, locality: 0.998, signal: 6.0, sinks: 4, heavy_frac: 0.002 }
    }
}

/// Generate one correlated (Q, K, V) head.
///
/// Token t's direction follows an AR(1) random walk
/// `u_t = ρ·u_{t-1} + √(1−ρ²)·ε_t` (unit-ish norm), so the block mean is a
/// faithful representative exactly when ρ (locality) is high — the regime
/// where SpargeAttn's compression is accurate.
pub fn generate(spec: &SyntheticSpec, rng: &mut Pcg) -> QkvSample {
    let (n, d) = (spec.n, spec.d);
    let rho = spec.locality.clamp(0.0, 0.9999);
    let nudge = (1.0 - rho * rho).sqrt();

    let mut dir = rng.gauss_vec(d);
    normalize(&mut dir);
    let mut q = Tensor::zeros(&[n, d]);
    let mut k = Tensor::zeros(&[n, d]);
    for t in 0..n {
        // advance the shared walk
        for x in dir.iter_mut() {
            *x = rho * *x + nudge * rng.gauss() / (d as f32).sqrt();
        }
        let mut dn = dir.clone();
        normalize(&mut dn);
        // Per-token noise is sized relative to the signal *norm*, not per
        // element: noise std 0.5·signal/√d gives a within-block cosine of
        // ≈ 1/(1+0.25) ≈ 0.8 — the regime real Q/K show in Fig. 4. (A fixed
        // per-element std of 1 would give the noise a norm of √d ≈ 8 and
        // drown any realistic signal.)
        let noise = if spec.signal > 0.0 { 0.5 * spec.signal / (d as f32).sqrt() } else { 1.0 };
        for (i, x) in q.row_mut(t).iter_mut().enumerate() {
            *x = spec.signal * dn[i] + rng.gauss() * noise;
        }
        for (i, x) in k.row_mut(t).iter_mut().enumerate() {
            *x = spec.signal * dn[i] + rng.gauss() * noise;
        }
    }
    // attention sinks: the first keys take a large shared direction and
    // every query gains a component along it (the StreamingLLM sink
    // artefact: sink scores dominate the row max everywhere, which is what
    // makes the stage-2 λ filter fire on distant blocks).
    if spec.sinks > 0 {
        let mut sink_dir = rng.gauss_vec(d);
        normalize(&mut sink_dir);
        for s in 0..spec.sinks.min(n) {
            for (x, &u) in k.row_mut(s).iter_mut().zip(&sink_dir) {
                *x = 3.0 * spec.signal * u + 0.2 * *x;
            }
        }
        for t in 0..n {
            for (x, &u) in q.row_mut(t).iter_mut().zip(&sink_dir) {
                *x += 0.5 * spec.signal * u;
            }
        }
    }
    // heavy hitters: scattered keys with boosted norm
    let n_heavy = ((n as f32) * spec.heavy_frac) as usize;
    for _ in 0..n_heavy {
        let t = rng.range(0, n);
        for x in k.row_mut(t) {
            *x *= 1.8;
        }
    }
    QkvSample { q, k, v: Tensor::randn(&[n, d], rng) }
}

/// Generate `h` heads with independent streams.
pub fn generate_heads(spec: &SyntheticSpec, heads: usize, seed: u64) -> Vec<QkvSample> {
    (0..heads)
        .map(|hd| {
            let mut rng = Pcg::new(seed, hd as u64 + 1);
            generate(spec, &mut rng)
        })
        .collect()
}

fn normalize(x: &mut [f32]) {
    let n = crate::tensor::ops::norm(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparge::metrics::avg_block_similarity;

    #[test]
    fn shapes_match_spec() {
        let mut rng = Pcg::seeded(1);
        let s = generate(&SyntheticSpec { n: 100, d: 16, ..Default::default() }, &mut rng);
        assert_eq!(s.q.shape(), &[100, 16]);
        assert_eq!(s.k.shape(), &[100, 16]);
        assert_eq!(s.v.shape(), &[100, 16]);
    }

    #[test]
    fn locality_raises_block_similarity() {
        let mut rng = Pcg::seeded(2);
        let local = generate(
            &SyntheticSpec { n: 512, d: 32, locality: 0.995, signal: 5.0, sinks: 0, heavy_frac: 0.0 },
            &mut rng,
        );
        let mut rng = Pcg::seeded(2);
        let random = generate(&SyntheticSpec::random(512, 32), &mut rng);
        let sim_local = avg_block_similarity(&local.q, 64);
        let sim_random = avg_block_similarity(&random.q, 64);
        assert!(sim_local > sim_random + 0.2, "local {sim_local} vs random {sim_random}");
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = SyntheticSpec::lm_like(64, 8);
        let a = generate(&spec, &mut Pcg::seeded(7));
        let b = generate(&spec, &mut Pcg::seeded(7));
        assert_eq!(a.q, b.q);
        assert_eq!(a.k, b.k);
    }

    #[test]
    fn heads_differ() {
        let spec = SyntheticSpec::lm_like(64, 8);
        let heads = generate_heads(&spec, 2, 9);
        assert_ne!(heads[0].q, heads[1].q);
    }

    #[test]
    fn sinks_have_larger_norm() {
        let mut rng = Pcg::seeded(3);
        let s = generate(&SyntheticSpec { n: 256, d: 16, sinks: 4, ..Default::default() }, &mut rng);
        let norm = |row: &[f32]| crate::tensor::ops::norm(row);
        let sink_norm: f32 = (0..4).map(|i| norm(s.k.row(i))).sum::<f32>() / 4.0;
        let rest_norm: f32 = (8..64).map(|i| norm(s.k.row(i))).sum::<f32>() / 56.0;
        assert!(sink_norm > rest_norm * 1.5);
    }
}
