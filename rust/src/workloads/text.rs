//! Text workloads for the LM-proxy experiments: a deterministic synthetic
//! byte corpus (for training the tiny LM through the runtime) and a
//! Needle-in-a-Haystack generator (Kamradt 2023; the paper's retrieval
//! benchmark for Llama3.1, Table 1 / Fig. 9 / Table 11).

use crate::util::rng::Pcg;

/// Vocabulary is raw bytes (0..=255); texts stay in ASCII.
pub const VOCAB_SIZE: usize = 256;

/// Generate a synthetic English-like byte corpus of length `len`.
///
/// A tiny phrase-level Markov sampler over a fixed word bank: enough
/// structure for a ~1M-param byte LM to reach clearly-below-uniform
/// perplexity in a few hundred steps, fully deterministic per seed.
pub fn corpus(len: usize, rng: &mut Pcg) -> Vec<u8> {
    const WORDS: &[&str] = &[
        "the", "model", "attention", "sparse", "block", "token", "video", "layer", "head",
        "fast", "slow", "mask", "value", "query", "key", "softmax", "kernel", "tile", "warp",
        "long", "context", "needle", "haystack", "memory", "cache", "speed", "accuracy",
    ];
    const CONNECT: &[&str] = &["is", "and", "of", "with", "in", "for", "to", "on"];
    let mut out = Vec::with_capacity(len + 16);
    while out.len() < len {
        // sentence: 4-9 words alternating bank/connector-ish
        let words = rng.range(4, 10);
        for w in 0..words {
            let word = if w % 2 == 1 && rng.chance(0.5) {
                CONNECT[rng.range(0, CONNECT.len())]
            } else {
                WORDS[rng.range(0, WORDS.len())]
            };
            out.extend_from_slice(word.as_bytes());
            out.push(b' ');
        }
        out.pop();
        out.extend_from_slice(b". ");
    }
    out.truncate(len);
    out
}

/// Corpus variant that interleaves key–value retrieval patterns with the
/// Markov text: `"code XY is 12345 . ... recall code XY : 12345 ."`.
///
/// Training on this teaches the byte-LM the induction/copy behaviour the
/// NIAH evaluation probes (a 0.9M-param LM trained on plain text alone
/// never develops 5-digit copy; with explicit patterns it does).
pub fn corpus_with_kv(len: usize, rng: &mut Pcg) -> Vec<u8> {
    let mut out = Vec::with_capacity(len + 64);
    while out.len() < len {
        // short filler, then a kv pair recalled after a short gap — the
        // whole pattern spans < ~170 bytes so most 256-byte training
        // windows contain a complete set+recall pair
        let filler = corpus(rng.range(12, 40), rng);
        out.extend_from_slice(&filler);
        out.extend_from_slice(b" ");
        let key = kv_key(rng);
        let val: u32 = rng.below(90_000) as u32 + 10_000;
        out.extend_from_slice(format!("code {key} is {val} . ").as_bytes());
        let gap = corpus(rng.range(8, 40), rng);
        out.extend_from_slice(&gap);
        out.extend_from_slice(format!(" recall code {key} : {val} . ").as_bytes());
    }
    out.truncate(len);
    out
}

fn kv_key(rng: &mut Pcg) -> String {
    let a = (b'A' + rng.below(26) as u8) as char;
    let b = (b'A' + rng.below(26) as u8) as char;
    format!("{a}{b}")
}

/// A Needle-in-a-Haystack instance.
#[derive(Clone, Debug)]
pub struct Niah {
    /// Full prompt: haystack with the needle inserted, then the question.
    pub prompt: Vec<u8>,
    /// The answer digits the model must retrieve.
    pub answer: Vec<u8>,
    /// Byte offset where the needle was inserted (for analysis).
    pub needle_pos: usize,
}

/// Build a NIAH instance of total prompt length `ctx_len`, with the needle
/// at relative `depth` ∈ [0,1]. Uses the same `code XY is NNNNN` /
/// `recall code XY :` format the KV corpus trains, so retrieval tests the
/// model's copy circuit rather than an untrained prompt format.
pub fn niah(ctx_len: usize, depth: f64, rng: &mut Pcg) -> Niah {
    let secret: u32 = rng.below(90_000) as u32 + 10_000; // 5 digits
    let key = kv_key(rng);
    let needle = format!("code {key} is {secret} . ");
    let question = format!(" recall code {key} : ");
    assert!(ctx_len > needle.len() + question.len() + 16, "ctx too small");

    let hay_len = ctx_len - needle.len() - question.len();
    let hay = corpus(hay_len, rng);
    let pos = ((hay_len as f64) * depth.clamp(0.0, 1.0)) as usize;

    let mut prompt = Vec::with_capacity(ctx_len);
    prompt.extend_from_slice(&hay[..pos]);
    prompt.extend_from_slice(needle.as_bytes());
    prompt.extend_from_slice(&hay[pos..]);
    prompt.extend_from_slice(question.as_bytes());
    Niah { prompt, answer: secret.to_string().into_bytes(), needle_pos: pos }
}

/// Score retrieval: fraction of answer bytes correctly produced
/// (greedy continuation `produced` vs expected digits).
pub fn niah_score(produced: &[u8], answer: &[u8]) -> f64 {
    if answer.is_empty() {
        return 1.0;
    }
    let hits = produced.iter().zip(answer).filter(|(a, b)| a == b).count();
    hits as f64 / answer.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_ascii_and_full_length() {
        let mut rng = Pcg::seeded(1);
        let c = corpus(5000, &mut rng);
        assert_eq!(c.len(), 5000);
        assert!(c.iter().all(|&b| b.is_ascii()));
        // has some structure: contains the word bank
        let s = String::from_utf8(c).unwrap();
        assert!(s.contains("attention"));
    }

    #[test]
    fn corpus_deterministic() {
        let a = corpus(1000, &mut Pcg::seeded(5));
        let b = corpus(1000, &mut Pcg::seeded(5));
        assert_eq!(a, b);
    }

    #[test]
    fn niah_prompt_has_exact_length_and_contains_needle() {
        let mut rng = Pcg::seeded(2);
        let n = niah(4096, 0.5, &mut rng);
        assert_eq!(n.prompt.len(), 4096);
        let text = String::from_utf8(n.prompt.clone()).unwrap();
        let ans = String::from_utf8(n.answer.clone()).unwrap();
        assert!(text.contains(&format!("is {ans} .")));
        assert!(text.ends_with(" : "));
    }

    #[test]
    fn kv_corpus_contains_recallable_pairs() {
        let mut rng = Pcg::seeded(9);
        let c = corpus_with_kv(4000, &mut rng);
        let text = String::from_utf8(c).unwrap();
        assert!(text.contains("code "));
        assert!(text.contains(" recall code "));
        // at least one 5-digit value appears twice (set + recall)
        let bytes = text.as_bytes();
        let mut found = false;
        for i in 0..bytes.len().saturating_sub(5) {
            let w = &text[i..i + 5];
            if w.bytes().all(|b| b.is_ascii_digit()) && text.matches(w).count() >= 2 {
                found = true;
                break;
            }
        }
        assert!(found, "no recalled value found");
    }

    #[test]
    fn niah_depth_controls_position() {
        let mut rng = Pcg::seeded(3);
        let early = niah(4096, 0.05, &mut rng);
        let mut rng = Pcg::seeded(3);
        let late = niah(4096, 0.95, &mut rng);
        assert!(early.needle_pos < late.needle_pos);
    }

    #[test]
    fn score_counts_matching_prefix_bytes() {
        assert_eq!(niah_score(b"12345", b"12345"), 1.0);
        assert_eq!(niah_score(b"12945", b"12345"), 0.8);
        assert_eq!(niah_score(b"", b"123"), 0.0);
    }
}
