//! Binary tensor-trace format shared between the Python build path and the
//! Rust runtime (little-endian, versioned):
//!
//! ```text
//! magic  u32  = 0x53504721   ("SPG!")
//! version u32 = 1
//! ntensor u32
//! per tensor: ndim u32, dims u32×ndim, f32 data (row-major, LE)
//! ```
//!
//! Used for QKV calibration dumps (`sparge tune --trace`), cross-layer
//! integration fixtures (pytest writes, cargo test reads), and model
//! weights exported by `python/compile/aot.py`.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: u32 = 0x5350_4721;
const VERSION: u32 = 1;

/// Write tensors to `path`.
pub fn save(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        w.write_all(&(t.ndim() as u32).to_le_bytes())?;
        for &dim in t.shape() {
            w.write_all(&(dim as u32).to_le_bytes())?;
        }
        for &v in t.data() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read tensors from `path`.
pub fn load(path: &Path) -> Result<Vec<Tensor>> {
    let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let magic = read_u32(&mut r)?;
    if magic != MAGIC {
        bail!("bad magic {magic:#x} in {}", path.display());
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported trace version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 8 {
            bail!("implausible ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut total = 1usize;
        for _ in 0..ndim {
            let d = read_u32(&mut r)? as usize;
            total = total.checked_mul(d).context("shape overflow")?;
            shape.push(d);
        }
        let mut buf = vec![0u8; total * 4];
        r.read_exact(&mut buf).context("truncated tensor data")?;
        let data: Vec<f32> =
            buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
        out.push(Tensor::from_vec(&shape, data));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("truncated header")?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sparge_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_multiple_tensors() {
        let mut rng = Pcg::seeded(1);
        let tensors = vec![
            Tensor::randn(&[4, 8], &mut rng),
            Tensor::randn(&[2, 3, 5], &mut rng),
            Tensor::from_vec(&[1], vec![42.0]),
        ];
        let p = tmp("roundtrip.spg");
        save(&p, &tensors).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in tensors.iter().zip(&back) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn empty_list_roundtrips() {
        let p = tmp("empty.spg");
        save(&p, &[]).unwrap();
        assert!(load(&p).unwrap().is_empty());
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.spg");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut rng = Pcg::seeded(2);
        let p = tmp("trunc.spg");
        save(&p, &[Tensor::randn(&[16, 16], &mut rng)]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p).is_err());
    }
}
