//! Model cards: the proxy-model suite standing in for the paper's
//! evaluation models (DESIGN.md §3 substitution table), with per-model
//! attention geometry, workload spec, and the paper's tuning bounds
//! (l1, l2 from §4.1 Implementation).

use crate::attention::types::AttnConfig;
use crate::workloads::{SyntheticSpec, VideoSpec};

/// Task family of a model card.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Text,
    Video,
    Image,
}

/// Workload description attached to a card.
#[derive(Clone, Copy, Debug)]
pub enum Workload {
    /// LM-style correlated tokens of the given sequence length.
    Lm(SyntheticSpec),
    /// Spatially-correlated latent grid.
    Grid(VideoSpec),
}

/// A proxy-model card.
#[derive(Clone, Copy, Debug)]
pub struct ModelCard {
    /// Paper model this proxies.
    pub name: &'static str,
    pub task: Task,
    pub heads: usize,
    pub layers: usize,
    pub workload: Workload,
    /// Tuning error bounds (paper §4.1).
    pub l1: f64,
    pub l2: f64,
}

impl ModelCard {
    pub fn attn_config(&self) -> AttnConfig {
        AttnConfig {
            bq: 128,
            bk: 64,
            causal: matches!(self.task, Task::Text),
            scale: None,
            cw: 4,
            row_offset: 0,
        }
    }

    pub fn seq_len(&self) -> usize {
        match self.workload {
            Workload::Lm(s) => s.n,
            Workload::Grid(g) => g.tokens(),
        }
    }
}

/// The Table-1 suite. `scale` divides sequence lengths to keep CPU runs
/// tractable (1 = paper scale).
pub fn suite(scale: usize) -> Vec<ModelCard> {
    let scale = scale.max(1);
    vec![
        ModelCard {
            name: "Llama3.1-proxy",
            task: Task::Text,
            heads: 4,
            layers: 4,
            workload: Workload::Lm(SyntheticSpec::lm_like(131_072 / scale, 64)),
            l1: 0.08,
            l2: 0.09,
        },
        ModelCard {
            name: "CogvideoX-proxy",
            task: Task::Video,
            heads: 4,
            layers: 4,
            workload: Workload::Grid(VideoSpec::cogvideo_proxy(scale)),
            l1: 0.05,
            l2: 0.06,
        },
        ModelCard {
            name: "Mochi-proxy",
            task: Task::Video,
            heads: 4,
            layers: 4,
            workload: Workload::Grid(VideoSpec::mochi_proxy(scale)),
            l1: 0.05,
            l2: 0.06,
        },
        ModelCard {
            name: "OpenSoraPlan-proxy",
            task: Task::Video,
            heads: 4,
            layers: 4,
            workload: Workload::Grid(VideoSpec {
                t: (38 / scale.max(1)).max(1),
                h: 32,
                w: 31,
                d: 64,
                smooth: 0.96,
                signal: 11.0,
            }),
            l1: 0.03,
            l2: 0.035,
        },
        ModelCard {
            name: "Flux-proxy",
            task: Task::Image,
            heads: 4,
            layers: 4,
            workload: Workload::Grid(VideoSpec::image_proxy()),
            l1: 0.07,
            l2: 0.08,
        },
        ModelCard {
            name: "SD3.5-proxy",
            task: Task::Image,
            heads: 4,
            layers: 4,
            workload: Workload::Grid(VideoSpec { t: 1, h: 67, w: 67, d: 64, smooth: 0.93, signal: 9.0 }),
            l1: 0.07,
            l2: 0.08,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_covers_all_tasks() {
        let s = suite(8);
        assert_eq!(s.len(), 6);
        assert!(s.iter().any(|c| c.task == Task::Text));
        assert!(s.iter().any(|c| c.task == Task::Video));
        assert!(s.iter().any(|c| c.task == Task::Image));
    }

    #[test]
    fn text_models_are_causal() {
        for c in suite(8) {
            assert_eq!(c.attn_config().causal, c.task == Task::Text, "{}", c.name);
        }
    }

    #[test]
    fn scale_reduces_seq_len() {
        let full = suite(1);
        let small = suite(8);
        for (f, s) in full.iter().zip(&small) {
            assert!(s.seq_len() <= f.seq_len(), "{}", f.name);
        }
    }

    #[test]
    fn paper_bounds_match_section_4_1() {
        let s = suite(1);
        let llama = s.iter().find(|c| c.name.contains("Llama")).unwrap();
        assert_eq!((llama.l1, llama.l2), (0.08, 0.09));
        let osp = s.iter().find(|c| c.name.contains("OpenSora")).unwrap();
        assert_eq!((osp.l1, osp.l2), (0.03, 0.035));
    }
}
