//! Experiment harness shared by `rust/benches/*`: one entry point per
//! attention *method* (ours + baselines), executed through the identical
//! sparse kernel so mask policy is the only variable, with TOPS
//! accounting per the paper's §4.1 definition.

use crate::attention::engine::{AttnEngine, Execution, SparsityPolicy};
use crate::attention::types::{AttnConfig, BlockMask, SkipStats};
use crate::baselines;
use crate::costmodel;
use crate::sparge::kernel::SpargeParams;
use crate::sparge::predict::{predict, PredictParams};
use crate::tensor::Tensor;
use crate::util::timer::time_once;
use crate::workloads::QkvSample;

/// An attention method under test.
#[derive(Clone, Debug)]
pub enum Method {
    /// Dense FlashAttention (the paper's Full-Attention row).
    Full,
    /// SpargeAttn with the given params (quant=true ⇒ Sage-integrated).
    Sparge(SpargeParams),
    /// Block-sparse MInference with a keep-budget ∈ (0,1].
    Minference { budget: f64 },
    /// FlexPrefill with cumulative threshold γ.
    FlexPrefill { gamma: f64 },
    /// StreamingLLM-style sink+window pattern.
    SlidingWindow { sinks: usize, window: usize },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Full => "Full-Attention".into(),
            Method::Sparge(p) if p.quant => "SpargeAttn".into(),
            Method::Sparge(_) => "SpargeAttn+FA2".into(),
            Method::Minference { budget } => format!("MInference ({:.1})", 1.0 - budget),
            Method::FlexPrefill { gamma } => format!("FlexPrefill (g={gamma})"),
            Method::SlidingWindow { .. } => "StreamingLLM".into(),
        }
    }
}

/// Result of one method run on one head.
#[derive(Clone, Debug)]
pub struct MethodRun {
    pub out: Tensor,
    pub stats: SkipStats,
    /// Total seconds (mask construction + sparse attention).
    pub seconds: f64,
    /// Seconds spent constructing the mask (prediction overhead).
    pub predict_seconds: f64,
}

impl MethodRun {
    /// Measured TOPS per the paper: ops of a *standard* attention divided
    /// by total latency including prediction.
    pub fn tops(&self, n_q: usize, n_k: usize, d: usize, causal: bool) -> f64 {
        costmodel::tops(costmodel::attention_ops(n_q, n_k, d, causal), self.seconds)
    }

    /// GPU-translated TOPS (see `costmodel`).
    pub fn gpu_tops(&self, dense_seconds: f64) -> f64 {
        let overhead = if dense_seconds > 0.0 { self.predict_seconds / dense_seconds } else { 0.0 };
        costmodel::gpu_translated_tops(&self.stats, overhead)
    }
}

/// Run a method on a single head, with query-block rows fanned across
/// `threads` workers inside the unified tiled driver (1 = serial; outputs
/// and stats are identical for every thread count). Engines are built via
/// [`AttnEngine`]; mask construction is timed separately from the kernel
/// so prediction overhead stays reportable (Table 3).
pub fn run_method_threads(s: &QkvSample, cfg: &AttnConfig, method: &Method, threads: usize) -> MethodRun {
    match method {
        Method::Full => {
            let engine = AttnEngine::builder().config(*cfg).execution(Execution::Threads(threads)).build();
            let (r, secs) = time_once(|| engine.attention(&s.q, &s.k, &s.v));
            MethodRun { out: r.out, stats: r.stats, seconds: secs, predict_seconds: 0.0 }
        }
        Method::Sparge(params) => {
            let (pred, t_pred) = time_once(|| predict(&s.q, &s.k, cfg, &params.predict_params()));
            let engine = AttnEngine::builder()
                .config(*cfg)
                .precision(params.precision())
                .policy(SparsityPolicy::External { mask: pred.mask, lambda: params.lambda })
                .execution(Execution::Threads(threads))
                .build();
            let (r, t_attn) = time_once(|| engine.attention(&s.q, &s.k, &s.v));
            MethodRun { out: r.out, stats: r.stats, seconds: t_pred + t_attn, predict_seconds: t_pred }
        }
        Method::Minference { budget } => {
            let (mask, t_pred) = time_once(|| baselines::minference_mask(&s.q, &s.k, cfg, *budget));
            run_with_mask(s, cfg, mask, t_pred, threads)
        }
        Method::FlexPrefill { gamma } => {
            let (mask, t_pred) = time_once(|| baselines::flexprefill_mask(&s.q, &s.k, cfg, *gamma));
            run_with_mask(s, cfg, mask, t_pred, threads)
        }
        Method::SlidingWindow { sinks, window } => {
            let (mask, t_pred) =
                time_once(|| baselines::sliding_window_mask(s.q.dim(0), s.k.dim(0), cfg, *sinks, *window));
            run_with_mask(s, cfg, mask, t_pred, threads)
        }
    }
}

/// Run a method on a single head, serial (the paper's single-kernel view).
pub fn run_method(s: &QkvSample, cfg: &AttnConfig, method: &Method) -> MethodRun {
    run_method_threads(s, cfg, method, 1)
}

fn run_with_mask(s: &QkvSample, cfg: &AttnConfig, mask: BlockMask, t_pred: f64, threads: usize) -> MethodRun {
    // baselines run through the identical kernel, no λ stage, no quant
    let engine = AttnEngine::builder()
        .config(*cfg)
        .policy(SparsityPolicy::External { mask, lambda: None })
        .execution(Execution::Threads(threads))
        .build();
    let (r, t_attn) = time_once(|| engine.attention(&s.q, &s.k, &s.v));
    MethodRun { out: r.out, stats: r.stats, seconds: t_pred + t_attn, predict_seconds: t_pred }
}

/// "Without self-similarity judge" ablation (Table 5/10): θ = −1 treats
/// every block as selective.
pub fn predict_without_judge(q: &Tensor, k: &Tensor, cfg: &AttnConfig, tau: f32) -> BlockMask {
    predict(q, k, cfg, &PredictParams { tau, theta: -1.0 }).mask
}

/// Standard env knob: full-scale benches (paper sequence lengths) are
/// opt-in because CPU dense attention at 128K takes minutes per point.
pub fn full_scale() -> bool {
    std::env::var("SPARGE_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Repetitions for timing loops in benches.
pub fn bench_reps() -> usize {
    std::env::var("SPARGE_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(3)
}

/// Row-parallel worker count for benches: `SPARGE_BENCH_THREADS`, default
/// one worker per core (capped like the pool).
pub fn bench_threads() -> usize {
    std::env::var("SPARGE_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(crate::util::threadpool::default_threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;
    use crate::workloads::{synthetic, SyntheticSpec};

    fn sample() -> QkvSample {
        let mut rng = Pcg::seeded(1);
        synthetic::generate(&SyntheticSpec::lm_like(512, 32), &mut rng)
    }

    #[test]
    fn all_methods_run_and_report() {
        let s = sample();
        let cfg = AttnConfig { bq: 64, bk: 32, causal: false, scale: None, cw: 2, row_offset: 0 };
        let methods = [
            Method::Full,
            Method::Sparge(SpargeParams::default()),
            Method::Minference { budget: 0.5 },
            Method::FlexPrefill { gamma: 0.95 },
            Method::SlidingWindow { sinks: 1, window: 4 },
        ];
        let dense = run_method(&s, &cfg, &Method::Full);
        for m in &methods {
            let r = run_method(&s, &cfg, m);
            assert_eq!(r.out.shape(), s.v.shape(), "{}", m.label());
            assert!(r.seconds > 0.0);
            assert!(r.tops(512, 512, 32, false) > 0.0);
            assert!(r.gpu_tops(dense.seconds) > 0.0);
            if matches!(m, Method::Full) {
                assert_eq!(r.stats.sparsity(), 0.0);
            }
        }
    }

    #[test]
    fn threaded_methods_match_serial() {
        let s = sample();
        let cfg = AttnConfig { bq: 64, bk: 32, causal: false, scale: None, cw: 2, row_offset: 0 };
        for m in [Method::Full, Method::Sparge(SpargeParams::default()), Method::Minference { budget: 0.5 }] {
            let serial = run_method(&s, &cfg, &m);
            let par = run_method_threads(&s, &cfg, &m, 4);
            assert_eq!(serial.out, par.out, "{}", m.label());
            assert_eq!(serial.stats, par.stats, "{}", m.label());
        }
    }

    #[test]
    fn labels_match_paper_convention() {
        assert_eq!(Method::Minference { budget: 0.5 }.label(), "MInference (0.5)");
        assert_eq!(Method::Full.label(), "Full-Attention");
        assert!(Method::Sparge(SpargeParams { quant: true, ..Default::default() }).label() == "SpargeAttn");
    }

    #[test]
    fn without_judge_masks_are_sparser_or_equal() {
        let s = sample();
        let cfg = AttnConfig { bq: 64, bk: 32, causal: false, scale: None, cw: 2, row_offset: 0 };
        let with = predict(&s.q, &s.k, &cfg, &PredictParams { tau: 0.9, theta: 0.5 }).mask;
        let without = predict_without_judge(&s.q, &s.k, &cfg, 0.9);
        assert!(without.sparsity() >= with.sparsity() - 1e-12);
    }
}
