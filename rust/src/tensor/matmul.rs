//! Matmul entry points for the attention hot path — thin wrappers that
//! route to the process-selected [`microkernel::Backend`]. Hand-tuned
//! for the attention shapes: tall-skinny A·Bᵀ (`matmul_nt`, used for
//! Q·Kᵀ where both operands are row-major over tokens) and A·B
//! (`matmul_nn`, used for P̃·V).
//!
//! Layout note: keeping K row-major and using the NT kernel means the
//! inner loop over `d` walks both operands contiguously — this is the
//! single biggest lever for the sparse engine's wall-clock (see
//! EXPERIMENTS.md §Perf).
//!
//! The kernel bodies live in [`microkernel`] (portable fixed-width-chunk
//! tier plus the `simd`-gated AVX2 tier); these free functions exist for
//! callers without an explicit dispatch handle and always use
//! [`Backend::select`]. The per-kernel determinism contract — which
//! kernels are bitwise across backends and which are allclose-vs-oracle
//! — is documented on [`microkernel`].

use super::microkernel::{self, Backend};
use super::Tensor;

/// C = A · Bᵀ where A is (m,k) and B is (n,k); C is (m,n).
/// Both inner loops stride contiguously over `k`.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (n, kb) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb, "matmul_nt inner dims: {k} vs {kb}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nt_into(a.data(), b.data(), c.data_mut(), m, n, k);
    c
}

/// NT kernel into a caller-provided buffer (len m*n). Fixed-order
/// (bitwise) tier — see [`microkernel`].
#[inline]
pub fn matmul_nt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    Backend::select().matmul_nt_into(a, b, c, m, n, k);
}

/// GEMV against row-major B: `c[j] = a · b[j]` for j in 0..n — the m=1
/// decode shape of the NT kernel (one query row scored against a key
/// block). Bitwise-identical to the per-[`dot`] loop it replaces on
/// every backend (the decode≡prefill parity contract in
/// `attention::engine` depends on every kernel path agreeing per row).
#[inline]
pub fn gemv_nt(a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    Backend::select().gemv_nt(a, b, c, n, k);
}

/// C = A · B where A is (m,k), B is (k,n); C is (m,n).
pub fn matmul_nn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (kb, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb, "matmul_nn inner dims: {k} vs {kb}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nn_acc(a.data(), b.data(), c.data_mut(), m, n, k, false, true);
    c
}

/// NN kernel, optionally accumulating into `c` (C += A·B when `acc`).
/// Oracle (allclose) tier — backends agree in summation order but may
/// fuse multiply-add rounding; see [`microkernel`].
///
/// `skip_zeros` gates the per-element `a == 0` early-out. Masked/sparse
/// callers (P̃ rows holding exact zeros from causal −∞ entries) keep it —
/// skipping a whole AXPY per masked key is the win the branch exists
/// for. Dense callers (no skipped blocks ⇒ few or no zeros) turn it off
/// so the inner loop carries no data-dependent branch per multiply.
/// Numerically the flag only changes whether exact-zero `a` terms
/// contribute `+= 0.0·b` no-ops, which can at most flip a `-0.0`
/// accumulator to `+0.0` (equal under IEEE `==` and every comparison in
/// this crate); with finite inputs both settings produce `==`-identical
/// results.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn matmul_nn_acc(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    acc: bool,
    skip_zeros: bool,
) {
    Backend::select().matmul_nn_acc(a, b, c, m, n, k, acc, skip_zeros);
}

/// Dot product of two equal-length slices (lane-parallel, fixed-order
/// tier).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    Backend::select().dot(a, b)
}

/// int8 NT kernel with i32 accumulation: C[i][j] = Σ_p a[i][p]·b[j][p].
/// Used by the SageAttention-quantized path (dequantized by the caller).
/// Exact integer arithmetic on every backend.
#[inline]
pub fn matmul_nt_i8(a: &[i8], b: &[i8], c: &mut [i32], m: usize, n: usize, k: usize) {
    Backend::select().matmul_nt_i8(a, b, c, m, n, k);
}

/// Re-exported so existing callers keep one name for the lane width.
pub use microkernel::LANES;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, Cases};
    use crate::util::rng::Pcg;

    fn naive_nt(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.dim(0), a.dim(1), b.dim(0));
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f32;
                for p in 0..k {
                    s += a.at2(i, p) * b.at2(j, p);
                }
                *c.at2_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn nt_matches_naive_property() {
        Cases::standard(101).check(|rng| {
            let m = rng.range(1, 17);
            let n = rng.range(1, 17);
            let k = rng.range(1, 33);
            let a = Tensor::randn(&[m, k], rng);
            let b = Tensor::randn(&[n, k], rng);
            let fast = matmul_nt(&a, &b);
            let slow = naive_nt(&a, &b);
            assert_allclose(fast.data(), slow.data(), 1e-4, 1e-4, "nt")
        });
    }

    #[test]
    fn nn_matches_nt_of_transpose() {
        Cases::standard(102).check(|rng| {
            let m = rng.range(1, 12);
            let k = rng.range(1, 12);
            let n = rng.range(1, 12);
            let a = Tensor::randn(&[m, k], rng);
            let b = Tensor::randn(&[k, n], rng);
            let via_nn = matmul_nn(&a, &b);
            let via_nt = matmul_nt(&a, &b.transpose2());
            assert_allclose(via_nn.data(), via_nt.data(), 1e-4, 1e-4, "nn-vs-nt")
        });
    }

    #[test]
    fn nn_accumulate() {
        let a = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2, 1], vec![3.0, 4.0]);
        let mut c = vec![10.0];
        matmul_nn_acc(a.data(), b.data(), &mut c, 1, 1, 2, true, true);
        assert_eq!(c[0], 10.0 + 11.0);
        matmul_nn_acc(a.data(), b.data(), &mut c, 1, 1, 2, false, true);
        assert_eq!(c[0], 11.0);
    }

    #[test]
    fn nn_zero_skip_flag_is_value_identical() {
        // The dense fast path (skip_zeros = false) must agree with the
        // sparse branch under `==` even when A holds exact zeros.
        Cases::standard(104).check(|rng| {
            let m = rng.range(1, 10);
            let k = rng.range(1, 10);
            let n = rng.range(1, 10);
            let mut a = Tensor::randn(&[m, k], rng);
            for x in a.data_mut() {
                if rng.chance(0.3) {
                    *x = 0.0;
                }
            }
            let b = Tensor::randn(&[k, n], rng);
            let mut c_skip = vec![0f32; m * n];
            let mut c_dense = vec![0f32; m * n];
            matmul_nn_acc(a.data(), b.data(), &mut c_skip, m, n, k, false, true);
            matmul_nn_acc(a.data(), b.data(), &mut c_dense, m, n, k, false, false);
            if c_skip != c_dense {
                return Err("zero-skip flag changed values".into());
            }
            Ok(())
        });
    }

    #[test]
    fn i8_kernel_exact() {
        let mut rng = Pcg::seeded(7);
        let (m, n, k) = (5, 6, 16);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..n * k).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
        let mut c = vec![0i32; m * n];
        matmul_nt_i8(&a, &b, &mut c, m, n, k);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k).map(|p| a[i * k + p] as i32 * b[j * k + p] as i32).sum();
                assert_eq!(c[i * n + j], want);
            }
        }
    }

    #[test]
    fn gemv_is_bitwise_identical_to_per_dot_loop() {
        // The decode-shape fast path must not change a single bit vs the
        // per-key `dot` loop it replaces — decode≡prefill parity rides on
        // every kernel path agreeing per row.
        Cases::standard(103).check(|rng| {
            let n = rng.range(1, 40);
            let k = rng.range(1, 70);
            let a = Tensor::randn(&[1, k], rng);
            let b = Tensor::randn(&[n, k], rng);
            let mut fast = vec![0f32; n];
            gemv_nt(a.data(), b.data(), &mut fast, n, k);
            let slow: Vec<f32> = (0..n).map(|j| dot(a.data(), &b.data()[j * k..(j + 1) * k])).collect();
            if fast != slow {
                return Err(format!("gemv diverged from dot at n={n} k={k}"));
            }
            // and matmul_nt_into with m = 1 routes through it
            let mut via_mm = vec![0f32; n];
            matmul_nt_into(a.data(), b.data(), &mut via_mm, 1, n, k);
            if via_mm != fast {
                return Err("m=1 matmul_nt_into diverged from gemv".into());
            }
            Ok(())
        });
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }
}
