//! Blocked matmul microkernels — the compute hot path of the L3 attention
//! engine. Hand-tuned for the attention shapes: tall-skinny A·Bᵀ
//! (`matmul_nt`, used for Q·Kᵀ where both operands are row-major over
//! tokens) and A·B (`matmul_nn`, used for P̃·V).
//!
//! Layout note: keeping K row-major and using the NT kernel means the inner
//! loop over `d` walks both operands contiguously — this is the single
//! biggest lever for the sparse engine's wall-clock (see EXPERIMENTS.md
//! §Perf).

use super::Tensor;

/// C = A · Bᵀ where A is (m,k) and B is (n,k); C is (m,n).
/// Both inner loops stride contiguously over `k`.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (n, kb) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb, "matmul_nt inner dims: {k} vs {kb}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nt_into(a.data(), b.data(), c.data_mut(), m, n, k);
    c
}

/// SIMD lane width for the explicit-lane kernels: 8 f32 = one AVX2
/// register; narrower targets still vectorize the lane arrays.
const LANES: usize = 8;

/// NT kernel into a caller-provided buffer (len m*n).
#[inline]
pub fn matmul_nt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    // 4-wide j-unroll × 8-wide explicit k-lanes: each a-row is dotted
    // against 4 b-rows at once, with [f32; 8] lane accumulators so the
    // inner loop compiles to packed FMAs instead of a scalar reduction
    // chain (the dot-product dependency is the bottleneck otherwise —
    // EXPERIMENTS.md §Perf).
    let n4 = n & !3;
    let kl = k & !(LANES - 1);
    let m2 = m & !1;
    let mut i = 0;
    // 2×4 register tile: each loaded B vector feeds two A rows, halving
    // B-side bandwidth (the NT kernel is bandwidth-bound once B spills L1).
    while i < m2 {
        let ar0 = &a[i * k..(i + 1) * k];
        let ar1 = &a[(i + 1) * k..(i + 2) * k];
        let (chead, ctail) = c[i * n..].split_at_mut(n);
        let cr0 = chead;
        let cr1 = &mut ctail[..n];
        let mut j = 0;
        while j < n4 {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let mut a00 = [0f32; LANES];
            let mut a01 = [0f32; LANES];
            let mut a02 = [0f32; LANES];
            let mut a03 = [0f32; LANES];
            let mut a10 = [0f32; LANES];
            let mut a11 = [0f32; LANES];
            let mut a12 = [0f32; LANES];
            let mut a13 = [0f32; LANES];
            let mut p = 0;
            while p < kl {
                for l in 0..LANES {
                    let av0 = ar0[p + l];
                    let av1 = ar1[p + l];
                    let bv0 = b0[p + l];
                    let bv1 = b1[p + l];
                    let bv2 = b2[p + l];
                    let bv3 = b3[p + l];
                    a00[l] += av0 * bv0;
                    a01[l] += av0 * bv1;
                    a02[l] += av0 * bv2;
                    a03[l] += av0 * bv3;
                    a10[l] += av1 * bv0;
                    a11[l] += av1 * bv1;
                    a12[l] += av1 * bv2;
                    a13[l] += av1 * bv3;
                }
                p += LANES;
            }
            let mut s = [
                a00.iter().sum::<f32>(),
                a01.iter().sum::<f32>(),
                a02.iter().sum::<f32>(),
                a03.iter().sum::<f32>(),
                a10.iter().sum::<f32>(),
                a11.iter().sum::<f32>(),
                a12.iter().sum::<f32>(),
                a13.iter().sum::<f32>(),
            ];
            while p < k {
                let av0 = ar0[p];
                let av1 = ar1[p];
                s[0] += av0 * b0[p];
                s[1] += av0 * b1[p];
                s[2] += av0 * b2[p];
                s[3] += av0 * b3[p];
                s[4] += av1 * b0[p];
                s[5] += av1 * b1[p];
                s[6] += av1 * b2[p];
                s[7] += av1 * b3[p];
                p += 1;
            }
            cr0[j] = s[0];
            cr0[j + 1] = s[1];
            cr0[j + 2] = s[2];
            cr0[j + 3] = s[3];
            cr1[j] = s[4];
            cr1[j + 1] = s[5];
            cr1[j + 2] = s[6];
            cr1[j + 3] = s[7];
            j += 4;
        }
        while j < n {
            let br = &b[j * k..(j + 1) * k];
            cr0[j] = dot(ar0, br);
            cr1[j] = dot(ar1, br);
            j += 1;
        }
        i += 2;
    }
    // odd tail row (and the whole matrix when m == 1): the GEMV kernel
    while i < m {
        gemv_nt(&a[i * k..(i + 1) * k], b, &mut c[i * n..(i + 1) * n], n, k);
        i += 1;
    }
}

/// GEMV against row-major B: `c[j] = a · b[j]` for j in 0..n — the m=1
/// decode shape of the NT kernel (one query row scored against a key
/// block), which the 2×4 register tile above cannot cover.
///
/// Same 4-wide j-unroll × `LANES`-wide lane accumulators as the tiled
/// kernel, so the single a-row is loaded once per 4 b-rows instead of
/// per `dot` call. Each output is accumulated lane-wise over the aligned
/// prefix, lane-summed, then finished with the sequential remainder —
/// the exact float evaluation order of [`dot`], so a row computed here
/// is **bitwise-identical** to the per-`dot` loop it replaces (the
/// decode≡prefill parity contract in `attention::engine` depends on
/// every kernel path agreeing per row).
#[inline]
pub fn gemv_nt(a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    debug_assert_eq!(a.len(), k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), n);
    let n4 = n & !3;
    let kl = k & !(LANES - 1);
    let mut j = 0;
    while j < n4 {
        let b0 = &b[j * k..(j + 1) * k];
        let b1 = &b[(j + 1) * k..(j + 2) * k];
        let b2 = &b[(j + 2) * k..(j + 3) * k];
        let b3 = &b[(j + 3) * k..(j + 4) * k];
        let mut a0 = [0f32; LANES];
        let mut a1 = [0f32; LANES];
        let mut a2 = [0f32; LANES];
        let mut a3 = [0f32; LANES];
        let mut p = 0;
        while p < kl {
            for l in 0..LANES {
                let av = a[p + l];
                a0[l] += av * b0[p + l];
                a1[l] += av * b1[p + l];
                a2[l] += av * b2[p + l];
                a3[l] += av * b3[p + l];
            }
            p += LANES;
        }
        let mut s = [
            a0.iter().sum::<f32>(),
            a1.iter().sum::<f32>(),
            a2.iter().sum::<f32>(),
            a3.iter().sum::<f32>(),
        ];
        while p < k {
            let av = a[p];
            s[0] += av * b0[p];
            s[1] += av * b1[p];
            s[2] += av * b2[p];
            s[3] += av * b3[p];
            p += 1;
        }
        c[j] = s[0];
        c[j + 1] = s[1];
        c[j + 2] = s[2];
        c[j + 3] = s[3];
        j += 4;
    }
    while j < n {
        c[j] = dot(a, &b[j * k..(j + 1) * k]);
        j += 1;
    }
}

/// C = A · B where A is (m,k), B is (k,n); C is (m,n).
pub fn matmul_nn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (kb, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb, "matmul_nn inner dims: {k} vs {kb}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nn_acc(a.data(), b.data(), c.data_mut(), m, n, k, false, true);
    c
}

/// NN kernel, optionally accumulating into `c` (C += A·B when `acc`).
/// i-k-j loop order: the inner loop is a contiguous AXPY over B's row `p`
/// and C's row `i`, which auto-vectorizes.
///
/// `skip_zeros` gates the per-element `a == 0` early-out. Masked/sparse
/// callers (P̃ rows holding exact zeros from causal −∞ entries) keep it —
/// skipping a whole AXPY per masked key is the win the branch exists
/// for. Dense callers (no skipped blocks ⇒ few or no zeros) turn it off
/// so the inner loop carries no data-dependent branch per multiply.
/// Numerically the flag only changes whether exact-zero `a` terms
/// contribute `+= 0.0·b` no-ops, which can at most flip a `-0.0`
/// accumulator to `+0.0` (equal under IEEE `==` and every comparison in
/// this crate); with finite inputs both settings produce `==`-identical
/// results.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn matmul_nn_acc(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    acc: bool,
    skip_zeros: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !acc {
        c.fill(0.0);
    }
    for i in 0..m {
        let cr = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if skip_zeros && av == 0.0 {
                continue;
            }
            let br = &b[p * n..(p + 1) * n];
            for (cv, &bv) in cr.iter_mut().zip(br) {
                *cv += av * bv;
            }
        }
    }
}

/// Dot product of two equal-length slices (lane-parallel).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let kl = k & !(LANES - 1);
    let mut acc = [0f32; LANES];
    let mut p = 0;
    while p < kl {
        for l in 0..LANES {
            acc[l] += a[p + l] * b[p + l];
        }
        p += LANES;
    }
    let mut s: f32 = acc.iter().sum();
    while p < k {
        s += a[p] * b[p];
        p += 1;
    }
    s
}

/// int8 NT kernel with i32 accumulation: C[i][j] = Σ_p a[i][p]·b[j][p].
/// Used by the SageAttention-quantized path (dequantized by the caller).
pub fn matmul_nt_i8(a: &[i8], b: &[i8], c: &mut [i32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let n4 = n & !3;
    let kl = k & !(LANES - 1);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let cr = &mut c[i * n..(i + 1) * n];
        let mut j = 0;
        while j < n4 {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let mut acc0 = [0i32; LANES];
            let mut acc1 = [0i32; LANES];
            let mut acc2 = [0i32; LANES];
            let mut acc3 = [0i32; LANES];
            let mut p = 0;
            while p < kl {
                for l in 0..LANES {
                    let av = ar[p + l] as i32;
                    acc0[l] += av * b0[p + l] as i32;
                    acc1[l] += av * b1[p + l] as i32;
                    acc2[l] += av * b2[p + l] as i32;
                    acc3[l] += av * b3[p + l] as i32;
                }
                p += LANES;
            }
            let (mut s0, mut s1, mut s2, mut s3) = (
                acc0.iter().sum::<i32>(),
                acc1.iter().sum::<i32>(),
                acc2.iter().sum::<i32>(),
                acc3.iter().sum::<i32>(),
            );
            while p < k {
                let av = ar[p] as i32;
                s0 += av * b0[p] as i32;
                s1 += av * b1[p] as i32;
                s2 += av * b2[p] as i32;
                s3 += av * b3[p] as i32;
                p += 1;
            }
            cr[j] = s0;
            cr[j + 1] = s1;
            cr[j + 2] = s2;
            cr[j + 3] = s3;
            j += 4;
        }
        while j < n {
            let br = &b[j * k..(j + 1) * k];
            let mut s = 0i32;
            for p in 0..k {
                s += ar[p] as i32 * br[p] as i32;
            }
            cr[j] = s;
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, Cases};
    use crate::util::rng::Pcg;

    fn naive_nt(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.dim(0), a.dim(1), b.dim(0));
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f32;
                for p in 0..k {
                    s += a.at2(i, p) * b.at2(j, p);
                }
                *c.at2_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn nt_matches_naive_property() {
        Cases::standard(101).check(|rng| {
            let m = rng.range(1, 17);
            let n = rng.range(1, 17);
            let k = rng.range(1, 33);
            let a = Tensor::randn(&[m, k], rng);
            let b = Tensor::randn(&[n, k], rng);
            let fast = matmul_nt(&a, &b);
            let slow = naive_nt(&a, &b);
            assert_allclose(fast.data(), slow.data(), 1e-4, 1e-4, "nt")
        });
    }

    #[test]
    fn nn_matches_nt_of_transpose() {
        Cases::standard(102).check(|rng| {
            let m = rng.range(1, 12);
            let k = rng.range(1, 12);
            let n = rng.range(1, 12);
            let a = Tensor::randn(&[m, k], rng);
            let b = Tensor::randn(&[k, n], rng);
            let via_nn = matmul_nn(&a, &b);
            let via_nt = matmul_nt(&a, &b.transpose2());
            assert_allclose(via_nn.data(), via_nt.data(), 1e-4, 1e-4, "nn-vs-nt")
        });
    }

    #[test]
    fn nn_accumulate() {
        let a = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2, 1], vec![3.0, 4.0]);
        let mut c = vec![10.0];
        matmul_nn_acc(a.data(), b.data(), &mut c, 1, 1, 2, true, true);
        assert_eq!(c[0], 10.0 + 11.0);
        matmul_nn_acc(a.data(), b.data(), &mut c, 1, 1, 2, false, true);
        assert_eq!(c[0], 11.0);
    }

    #[test]
    fn nn_zero_skip_flag_is_value_identical() {
        // The dense fast path (skip_zeros = false) must agree with the
        // sparse branch under `==` even when A holds exact zeros.
        Cases::standard(104).check(|rng| {
            let m = rng.range(1, 10);
            let k = rng.range(1, 10);
            let n = rng.range(1, 10);
            let mut a = Tensor::randn(&[m, k], rng);
            for x in a.data_mut() {
                if rng.chance(0.3) {
                    *x = 0.0;
                }
            }
            let b = Tensor::randn(&[k, n], rng);
            let mut c_skip = vec![0f32; m * n];
            let mut c_dense = vec![0f32; m * n];
            matmul_nn_acc(a.data(), b.data(), &mut c_skip, m, n, k, false, true);
            matmul_nn_acc(a.data(), b.data(), &mut c_dense, m, n, k, false, false);
            if c_skip != c_dense {
                return Err("zero-skip flag changed values".into());
            }
            Ok(())
        });
    }

    #[test]
    fn i8_kernel_exact() {
        let mut rng = Pcg::seeded(7);
        let (m, n, k) = (5, 6, 16);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..n * k).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
        let mut c = vec![0i32; m * n];
        matmul_nt_i8(&a, &b, &mut c, m, n, k);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k).map(|p| a[i * k + p] as i32 * b[j * k + p] as i32).sum();
                assert_eq!(c[i * n + j], want);
            }
        }
    }

    #[test]
    fn gemv_is_bitwise_identical_to_per_dot_loop() {
        // The decode-shape fast path must not change a single bit vs the
        // per-key `dot` loop it replaces — decode≡prefill parity rides on
        // every kernel path agreeing per row.
        Cases::standard(103).check(|rng| {
            let n = rng.range(1, 40);
            let k = rng.range(1, 70);
            let a = Tensor::randn(&[1, k], rng);
            let b = Tensor::randn(&[n, k], rng);
            let mut fast = vec![0f32; n];
            gemv_nt(a.data(), b.data(), &mut fast, n, k);
            let slow: Vec<f32> = (0..n).map(|j| dot(a.data(), &b.data()[j * k..(j + 1) * k])).collect();
            if fast != slow {
                return Err(format!("gemv diverged from dot at n={n} k={k}"));
            }
            // and matmul_nt_into with m = 1 routes through it
            let mut via_mm = vec![0f32; n];
            matmul_nt_into(a.data(), b.data(), &mut via_mm, 1, n, k);
            if via_mm != fast {
                return Err("m=1 matmul_nt_into diverged from gemv".into());
            }
            Ok(())
        });
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }
}
