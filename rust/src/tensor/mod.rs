//! Dense f32 tensor substrate: contiguous storage, runtime-dispatched
//! matmul microkernels, row-wise softmax ops, and SageAttention-style
//! per-block INT8 quantization.
//!
//! The compute kernels live in three tiers (see [`microkernel`] for the
//! full story and the per-kernel determinism contract):
//!
//! 1. **scalar reference** — naive loops in tests; defines values.
//! 2. **portable fixed-width chunks** — explicit lane accumulators that
//!    vectorize on any target; defines the bitwise order.
//! 3. **`core::arch` AVX2(+FMA)** — behind the `simd` cargo feature with
//!    runtime CPU dispatch ([`microkernel::Backend::select`]).
//!
//! The free functions in [`matmul`] are thin wrappers over
//! [`microkernel::Backend::select`]; hot paths that carry an explicit
//! dispatch handle (the attention pipeline's `ScoreKernel` seam) call
//! the [`microkernel::Backend`] methods directly.

pub mod matmul;
pub mod microkernel;
pub mod ops;
pub mod quant;

use std::fmt;

/// A contiguous row-major f32 tensor with up to 4 dimensions.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor filled with `v`.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Wrap existing data; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} needs {} elements, got {}", shape, n, data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// Shape slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Size of dimension `d`.
    pub fn dim(&self, d: usize) -> usize {
        self.shape[d]
    }

    /// Raw data.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw data.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into raw data.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// 2-D element accessor (row-major).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// 2-D mutable accessor.
    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.ndim(), 2);
        &mut self.data[i * self.shape[1] + j]
    }

    /// Row `i` of a 2-D tensor.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Mutable row `i` of a 2-D tensor.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Append whole rows to a 2-D tensor, growing dim 0 in place (the KV
    /// cache append path — no reshape, no copy of existing rows, and no
    /// allocation while the data fits reserved capacity).
    pub fn append_rows(&mut self, rows: &[f32]) {
        debug_assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        assert!(w > 0 && rows.len() % w == 0, "append_rows: {} elems onto width {w}", rows.len());
        self.data.extend_from_slice(rows);
        self.shape[0] += rows.len() / w;
    }

    /// Reserve exact capacity for `rows` total rows of a 2-D tensor, so a
    /// caller-managed growth policy (amortized block doubling) decides
    /// when reallocation happens — not the allocator on every append.
    pub fn reserve_rows(&mut self, rows: usize) {
        debug_assert_eq!(self.ndim(), 2);
        let need = rows * self.shape[1];
        self.data.reserve_exact(need.saturating_sub(self.data.len()));
    }

    /// Copy rows [r0, r1) of a 2-D tensor into a new (r1-r0, cols) tensor.
    pub fn rows(&self, r0: usize, r1: usize) -> Tensor {
        debug_assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        Tensor::from_vec(&[r1 - r0, w], self.data[r0 * w..r1 * w].to_vec())
    }

    /// Gaussian-random tensor (for tests / workloads).
    pub fn randn(shape: &[usize], rng: &mut crate::util::rng::Pcg) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.gauss_vec(n) }
    }

    /// Transpose of a 2-D tensor.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::from_vec(&[c, r], out)
    }

    /// Elementwise maximum of |x|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.rows(1, 2).data(), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg::seeded(1);
        let t = Tensor::randn(&[5, 7], &mut rng);
        let tt = t.transpose2().transpose2();
        assert_eq!(t, tt);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn append_rows_grows_in_place_within_capacity() {
        let mut t = Tensor::from_vec(&[1, 3], vec![1., 2., 3.]);
        t.reserve_rows(3);
        let cap = t.data.capacity();
        t.append_rows(&[4., 5., 6., 7., 8., 9.]);
        assert_eq!(t.shape(), &[3, 3]);
        assert_eq!(t.row(2), &[7., 8., 9.]);
        assert_eq!(t.data.capacity(), cap, "append within reserve must not reallocate");
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]);
        let r = t.reshape(&[2, 2]);
        assert_eq!(r.at2(1, 0), 3.0);
    }

    #[test]
    fn abs_max_and_scale() {
        let mut t = Tensor::from_vec(&[3], vec![-2.0, 1.0, 0.5]);
        assert_eq!(t.abs_max(), 2.0);
        t.scale(2.0);
        assert_eq!(t.data(), &[-4.0, 2.0, 1.0]);
    }
}
