//! The portable fixed-width-chunk tier: explicit `[f32; LANES]` lane
//! accumulators that compile to packed SIMD on any target without any
//! `core::arch` code.
//!
//! These bodies **define the bitwise reference order** for the
//! fixed-order kernel tier (see the module docs in [`super`]): lane `l`
//! accumulates the terms at positions `p ≡ l (mod LANES)` of the aligned
//! prefix in increasing `p` with unfused multiply-then-add, the lanes
//! are summed sequentially `0..LANES`, then the remainder is added
//! scalarly in increasing `p`. Any other backend claiming the bitwise
//! tier must reproduce this order exactly.

use super::LANES;

/// NT kernel into a caller-provided buffer (len m*n).
///
/// 4-wide j-unroll × `LANES`-wide explicit k-lanes: each a-row is dotted
/// against 4 b-rows at once, with `[f32; LANES]` lane accumulators so
/// the inner loop compiles to packed FMAs instead of a scalar reduction
/// chain (the dot-product dependency is the bottleneck otherwise —
/// EXPERIMENTS.md §Perf). 2×4 register tile: each loaded B vector feeds
/// two A rows, halving B-side bandwidth (the NT kernel is
/// bandwidth-bound once B spills L1).
pub(super) fn matmul_nt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    let n4 = n & !3;
    let kl = k & !(LANES - 1);
    let m2 = m & !1;
    let mut i = 0;
    while i < m2 {
        let ar0 = &a[i * k..(i + 1) * k];
        let ar1 = &a[(i + 1) * k..(i + 2) * k];
        let (chead, ctail) = c[i * n..].split_at_mut(n);
        let cr0 = chead;
        let cr1 = &mut ctail[..n];
        let mut j = 0;
        while j < n4 {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let mut a00 = [0f32; LANES];
            let mut a01 = [0f32; LANES];
            let mut a02 = [0f32; LANES];
            let mut a03 = [0f32; LANES];
            let mut a10 = [0f32; LANES];
            let mut a11 = [0f32; LANES];
            let mut a12 = [0f32; LANES];
            let mut a13 = [0f32; LANES];
            let mut p = 0;
            while p < kl {
                for l in 0..LANES {
                    let av0 = ar0[p + l];
                    let av1 = ar1[p + l];
                    let bv0 = b0[p + l];
                    let bv1 = b1[p + l];
                    let bv2 = b2[p + l];
                    let bv3 = b3[p + l];
                    a00[l] += av0 * bv0;
                    a01[l] += av0 * bv1;
                    a02[l] += av0 * bv2;
                    a03[l] += av0 * bv3;
                    a10[l] += av1 * bv0;
                    a11[l] += av1 * bv1;
                    a12[l] += av1 * bv2;
                    a13[l] += av1 * bv3;
                }
                p += LANES;
            }
            let mut s = [
                a00.iter().sum::<f32>(),
                a01.iter().sum::<f32>(),
                a02.iter().sum::<f32>(),
                a03.iter().sum::<f32>(),
                a10.iter().sum::<f32>(),
                a11.iter().sum::<f32>(),
                a12.iter().sum::<f32>(),
                a13.iter().sum::<f32>(),
            ];
            while p < k {
                let av0 = ar0[p];
                let av1 = ar1[p];
                s[0] += av0 * b0[p];
                s[1] += av0 * b1[p];
                s[2] += av0 * b2[p];
                s[3] += av0 * b3[p];
                s[4] += av1 * b0[p];
                s[5] += av1 * b1[p];
                s[6] += av1 * b2[p];
                s[7] += av1 * b3[p];
                p += 1;
            }
            cr0[j] = s[0];
            cr0[j + 1] = s[1];
            cr0[j + 2] = s[2];
            cr0[j + 3] = s[3];
            cr1[j] = s[4];
            cr1[j + 1] = s[5];
            cr1[j + 2] = s[6];
            cr1[j + 3] = s[7];
            j += 4;
        }
        while j < n {
            let br = &b[j * k..(j + 1) * k];
            cr0[j] = dot(ar0, br);
            cr1[j] = dot(ar1, br);
            j += 1;
        }
        i += 2;
    }
    // odd tail row (and the whole matrix when m == 1): the GEMV kernel
    while i < m {
        gemv_nt(&a[i * k..(i + 1) * k], b, &mut c[i * n..(i + 1) * n], n, k);
        i += 1;
    }
}

/// GEMV against row-major B: `c[j] = a · b[j]` for j in 0..n — the m=1
/// decode shape of the NT kernel (one query row scored against a key
/// block), which the 2×4 register tile above cannot cover.
///
/// Same 4-wide j-unroll × `LANES`-wide lane accumulators as the tiled
/// kernel, so the single a-row is loaded once per 4 b-rows instead of
/// per `dot` call. Each output is accumulated lane-wise over the aligned
/// prefix, lane-summed, then finished with the sequential remainder —
/// the exact float evaluation order of [`dot`], so a row computed here
/// is **bitwise-identical** to the per-`dot` loop it replaces (the
/// decode≡prefill parity contract in `attention::engine` depends on
/// every kernel path agreeing per row).
pub(super) fn gemv_nt(a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    let n4 = n & !3;
    let kl = k & !(LANES - 1);
    let mut j = 0;
    while j < n4 {
        let b0 = &b[j * k..(j + 1) * k];
        let b1 = &b[(j + 1) * k..(j + 2) * k];
        let b2 = &b[(j + 2) * k..(j + 3) * k];
        let b3 = &b[(j + 3) * k..(j + 4) * k];
        let mut a0 = [0f32; LANES];
        let mut a1 = [0f32; LANES];
        let mut a2 = [0f32; LANES];
        let mut a3 = [0f32; LANES];
        let mut p = 0;
        while p < kl {
            for l in 0..LANES {
                let av = a[p + l];
                a0[l] += av * b0[p + l];
                a1[l] += av * b1[p + l];
                a2[l] += av * b2[p + l];
                a3[l] += av * b3[p + l];
            }
            p += LANES;
        }
        let mut s = [
            a0.iter().sum::<f32>(),
            a1.iter().sum::<f32>(),
            a2.iter().sum::<f32>(),
            a3.iter().sum::<f32>(),
        ];
        while p < k {
            let av = a[p];
            s[0] += av * b0[p];
            s[1] += av * b1[p];
            s[2] += av * b2[p];
            s[3] += av * b3[p];
            p += 1;
        }
        c[j] = s[0];
        c[j + 1] = s[1];
        c[j + 2] = s[2];
        c[j + 3] = s[3];
        j += 4;
    }
    while j < n {
        c[j] = dot(a, &b[j * k..(j + 1) * k]);
        j += 1;
    }
}

/// Dot product of two equal-length slices (lane-parallel).
pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len();
    let kl = k & !(LANES - 1);
    let mut acc = [0f32; LANES];
    let mut p = 0;
    while p < kl {
        for l in 0..LANES {
            acc[l] += a[p + l] * b[p + l];
        }
        p += LANES;
    }
    let mut s: f32 = acc.iter().sum();
    while p < k {
        s += a[p] * b[p];
        p += 1;
    }
    s
}

/// NN kernel, optionally accumulating into `c` (C += A·B when `acc`).
/// i-k-j loop order: the inner loop is a contiguous AXPY over B's row `p`
/// and C's row `i`, which auto-vectorizes.
///
/// `skip_zeros` gates the per-element `a == 0` early-out. Masked/sparse
/// callers (P̃ rows holding exact zeros from causal −∞ entries) keep it —
/// skipping a whole AXPY per masked key is the win the branch exists
/// for. Dense callers (no skipped blocks ⇒ few or no zeros) turn it off
/// so the inner loop carries no data-dependent branch per multiply.
/// Numerically the flag only changes whether exact-zero `a` terms
/// contribute `+= 0.0·b` no-ops, which can at most flip a `-0.0`
/// accumulator to `+0.0` (equal under IEEE `==` and every comparison in
/// this crate); with finite inputs both settings produce `==`-identical
/// results.
#[allow(clippy::too_many_arguments)]
pub(super) fn matmul_nn_acc(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    acc: bool,
    skip_zeros: bool,
) {
    if !acc {
        c.fill(0.0);
    }
    for i in 0..m {
        let cr = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if skip_zeros && av == 0.0 {
                continue;
            }
            let br = &b[p * n..(p + 1) * n];
            for (cv, &bv) in cr.iter_mut().zip(br) {
                *cv += av * bv;
            }
        }
    }
}

/// Column-wise row accumulate: `sums[j] += x[r][j]` for r in 0..rows —
/// the KPool block-mean reduction. Every output element receives its
/// additions in increasing-`r` order regardless of how the inner `j`
/// sweep is vectorized (each column is an independent chain), so any
/// backend with the same per-column row order is bitwise-identical.
pub(super) fn sum_rows_acc(x: &[f32], sums: &mut [f32], rows: usize, d: usize) {
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        for (s, &v) in sums.iter_mut().zip(row) {
            *s += v;
        }
    }
}

/// int8 NT kernel with i32 accumulation: C[i][j] = Σ_p a[i][p]·b[j][p].
/// Used by the SageAttention-quantized path (dequantized by the caller).
/// Exact integer arithmetic — order-free, trivially bitwise.
pub(super) fn matmul_nt_i8(a: &[i8], b: &[i8], c: &mut [i32], m: usize, n: usize, k: usize) {
    let n4 = n & !3;
    let kl = k & !(LANES - 1);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let cr = &mut c[i * n..(i + 1) * n];
        let mut j = 0;
        while j < n4 {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let mut acc0 = [0i32; LANES];
            let mut acc1 = [0i32; LANES];
            let mut acc2 = [0i32; LANES];
            let mut acc3 = [0i32; LANES];
            let mut p = 0;
            while p < kl {
                for l in 0..LANES {
                    let av = ar[p + l] as i32;
                    acc0[l] += av * b0[p + l] as i32;
                    acc1[l] += av * b1[p + l] as i32;
                    acc2[l] += av * b2[p + l] as i32;
                    acc3[l] += av * b3[p + l] as i32;
                }
                p += LANES;
            }
            let (mut s0, mut s1, mut s2, mut s3) = (
                acc0.iter().sum::<i32>(),
                acc1.iter().sum::<i32>(),
                acc2.iter().sum::<i32>(),
                acc3.iter().sum::<i32>(),
            );
            while p < k {
                let av = ar[p] as i32;
                s0 += av * b0[p] as i32;
                s1 += av * b1[p] as i32;
                s2 += av * b2[p] as i32;
                s3 += av * b3[p] as i32;
                p += 1;
            }
            cr[j] = s0;
            cr[j + 1] = s1;
            cr[j + 2] = s2;
            cr[j + 3] = s3;
            j += 4;
        }
        while j < n {
            let br = &b[j * k..(j + 1) * k];
            let mut s = 0i32;
            for p in 0..k {
                s += ar[p] as i32 * br[p] as i32;
            }
            cr[j] = s;
            j += 1;
        }
    }
}
