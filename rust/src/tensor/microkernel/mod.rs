//! Runtime-dispatched microkernels for the three flop-dominant inner
//! loops of the attention engine: f32 QKᵀ (`matmul_nt_into` / `gemv_nt` /
//! `dot`), the INT8 i8×i8→i32 dot (`matmul_nt_i8`), and the P̃·V
//! accumulate (`matmul_nn_acc`).
//!
//! ## The three-tier kernel story
//!
//! 1. **Scalar reference** — the naive triple loops in tests (and the
//!    per-`dot` loops the fast paths replaced). They define *values*;
//!    nothing ships them on a hot path.
//! 2. **Portable fixed-width chunks** ([`portable`]) — explicit
//!    `[f32; LANES]` lane accumulators over the aligned prefix, the lanes
//!    summed sequentially `0..LANES`, then a scalar remainder. Compiles
//!    to packed SIMD on any target with no `core::arch` code, and
//!    *defines the bitwise reference order* for the fixed-order tier
//!    below. Always built; the fallback when `simd` is off, the target
//!    is not x86_64, or the CPU lacks AVX2+FMA.
//! 3. **`core::arch` kernels** ([`avx2`], behind the `simd` cargo
//!    feature on x86_64) — hand-written AVX2(+FMA) with runtime
//!    CPU-feature dispatch via [`Backend::select`].
//!
//! ## Per-kernel determinism tiers
//!
//! Every kernel is placed in exactly one of two documented tiers (the
//! decision ROADMAP item 2 demanded), enforced by the property tests in
//! this module:
//!
//! - **Fixed-order (bitwise) tier** — `matmul_nt_into`, `gemv_nt`,
//!   `dot`, `matmul_nt_i8`, `sum_rows_acc`. Each output element is produced in one
//!   platform-independent float evaluation order: lane `l` of a
//!   `LANES`-wide accumulator takes the terms at positions `p ≡ l (mod
//!   LANES)` of the aligned prefix in increasing `p` with *unfused*
//!   multiply-then-add, the lanes are summed sequentially `0..LANES`,
//!   and the `k % LANES` remainder is added scalarly in increasing `p`.
//!   The AVX2 kernels keep that exact order (`_mm256_mul_ps` +
//!   `_mm256_add_ps` — never FMA, whose single rounding would change
//!   bits — and an extract-then-sequential-sum lane reduction), so
//!   **every backend returns bitwise-identical results**. The INT8
//!   kernel is exact integer arithmetic, order-free, hence trivially
//!   bitwise. The engine's decode≡prefill and cross-exec bitwise
//!   contracts ride on this tier.
//! - **Oracle (allclose) tier** — `matmul_nn_acc`. The P̃·V accumulate is
//!   a bandwidth-bound AXPY sweep where fused multiply-add is the whole
//!   point of the hardware; pinning it to unfused portable bits would
//!   forfeit the win. Backends keep the same *summation order* (per
//!   output, terms in increasing `p`) but may fuse the multiply-add
//!   rounding, so results are **allclose — not bitwise — across
//!   backends**, within `|Δ| ≤ k·ε·Σ|a·b|` (tested at rel/abs 1e-5
//!   against the scalar oracle). Within one process the backend is fixed
//!   (one [`Backend::select`] per process, or one explicit handle per
//!   engine), so all *in-process* bitwise contracts — across exec modes,
//!   pool sizes, drivers, decode-vs-prefill — still hold exactly: the
//!   tier only relaxes parity *between* backends.
//!
//! The pipeline-level statement of these contracts lives next to the
//! split-KV merge rule in [`crate::attention::pipeline`].

use std::sync::atomic::{AtomicU8, Ordering};

mod portable;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2;

/// SIMD lane width shared by every tier: 8 f32 = one AVX2 register.
/// Narrower targets still vectorize the portable lane arrays.
pub const LANES: usize = 8;

/// One microkernel backend: a concrete implementation of the five hot
/// loops. `Copy` so kernels and tiles carry it by value as a dispatch
/// handle.
///
/// Invariant: [`Backend::Avx2`] is only constructed after runtime
/// detection says the CPU has AVX2+FMA ([`Backend::select`] /
/// [`Backend::all`] uphold this); calling its kernels on an unsupported
/// CPU is undefined behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The portable fixed-width-chunk tier (always available).
    Portable,
    /// Hand-written `core::arch` AVX2(+FMA) kernels.
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    Avx2,
}

const TAG_UNSET: u8 = 0;
const TAG_PORTABLE: u8 = 1;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
const TAG_AVX2: u8 = 2;

/// Process-wide cached detection result (no allocation, hot-path safe).
static SELECTED: AtomicU8 = AtomicU8::new(TAG_UNSET);

impl Backend {
    /// The best backend the running CPU supports, detected once per
    /// process and cached in an atomic. With the `simd` feature off (or
    /// off x86_64) this is always [`Backend::Portable`].
    #[inline]
    pub fn select() -> Backend {
        match SELECTED.load(Ordering::Relaxed) {
            TAG_PORTABLE => Backend::Portable,
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            TAG_AVX2 => Backend::Avx2,
            _ => {
                let b = Backend::detect();
                SELECTED.store(b.tag(), Ordering::Relaxed);
                b
            }
        }
    }

    fn detect() -> Backend {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            return Backend::Avx2;
        }
        Backend::Portable
    }

    fn tag(self) -> u8 {
        match self {
            Backend::Portable => TAG_PORTABLE,
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Backend::Avx2 => TAG_AVX2,
        }
    }

    /// Every backend runnable on this CPU (for parity tests and the
    /// fig10 microkernel scoreboard).
    pub fn all() -> &'static [Backend] {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if Backend::select() == Backend::Avx2 {
            return &[Backend::Portable, Backend::Avx2];
        }
        &[Backend::Portable]
    }

    /// Short display name.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Portable => "portable",
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Backend::Avx2 => "avx2",
        }
    }

    /// C = A·Bᵀ into `c` (len m·n); A is (m,k), B is (n,k) row-major.
    /// Fixed-order tier: bitwise-identical across backends.
    #[inline]
    pub fn matmul_nt_into(self, a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        match self {
            Backend::Portable => portable::matmul_nt_into(a, b, c, m, n, k),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: Avx2 is only constructed after runtime detection.
            Backend::Avx2 => unsafe { avx2::matmul_nt_into(a, b, c, m, n, k) },
        }
    }

    /// `c[j] = a · b[j]` for row-major B (n,k) — the m=1 decode shape of
    /// the NT kernel. Fixed-order tier: bitwise-identical across
    /// backends *and* to the per-[`Backend::dot`] loop it replaces.
    #[inline]
    pub fn gemv_nt(self, a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
        debug_assert_eq!(a.len(), k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), n);
        match self {
            Backend::Portable => portable::gemv_nt(a, b, c, n, k),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: Avx2 is only constructed after runtime detection.
            Backend::Avx2 => unsafe { avx2::gemv_nt(a, b, c, n, k) },
        }
    }

    /// Dot product of two equal-length slices. Fixed-order tier.
    #[inline]
    pub fn dot(self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Backend::Portable => portable::dot(a, b),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: Avx2 is only constructed after runtime detection.
            Backend::Avx2 => unsafe { avx2::dot(a, b) },
        }
    }

    /// INT8 NT kernel with i32 accumulation:
    /// `c[i][j] = Σ_p a[i][p]·b[j][p]`. Exact integer arithmetic —
    /// trivially fixed-order tier.
    #[inline]
    pub fn matmul_nt_i8(self, a: &[i8], b: &[i8], c: &mut [i32], m: usize, n: usize, k: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        match self {
            Backend::Portable => portable::matmul_nt_i8(a, b, c, m, n, k),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: Avx2 is only constructed after runtime detection.
            Backend::Avx2 => unsafe { avx2::matmul_nt_i8(a, b, c, m, n, k) },
        }
    }

    /// Column-wise row accumulate: `sums[j] += x[r][j]` for r in
    /// 0..rows — the stage-1 `KPool` block-mean reduction. Fixed-order
    /// tier, and trivially so: each column is an independent pure-
    /// addition chain evaluated in increasing `r`, with no cross-lane
    /// reduction anywhere, so lane width cannot change any bit and
    /// every backend matches the scalar `iter_mut().zip(row)` sweep it
    /// replaces bitwise.
    #[inline]
    pub fn sum_rows_acc(self, x: &[f32], sums: &mut [f32], rows: usize, d: usize) {
        debug_assert!(x.len() >= rows * d);
        debug_assert!(sums.len() >= d);
        match self {
            Backend::Portable => portable::sum_rows_acc(x, sums, rows, d),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: Avx2 is only constructed after runtime detection.
            Backend::Avx2 => unsafe { avx2::sum_rows_acc(x, sums, rows, d) },
        }
    }

    /// NN kernel (`C (+)= A·B`; A is (m,k), B is (k,n)), optionally
    /// accumulating, with the `skip_zeros` AXPY early-out of the sparse
    /// P̃·V path. **Oracle tier**: backends share the summation order but
    /// may fuse multiply-add, so results are allclose — not bitwise —
    /// across backends (bitwise within any one backend).
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_nn_acc(
        self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        n: usize,
        k: usize,
        acc: bool,
        skip_zeros: bool,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        match self {
            Backend::Portable => portable::matmul_nn_acc(a, b, c, m, n, k, acc, skip_zeros),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: Avx2 is only constructed after runtime detection.
            Backend::Avx2 => unsafe { avx2::matmul_nn_acc(a, b, c, m, n, k, acc, skip_zeros) },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, Cases};

    /// Scalar oracle for NT: per-output sequential sum (values only —
    /// the bitwise reference is the *portable* backend).
    fn naive_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f32;
                for p in 0..k {
                    s += a[i * k + p] * b[j * k + p];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    /// Scalar oracle for NN-accumulate. Per output element this sums
    /// `a[i][p]·b[p][j]` in increasing `p` with unfused mul+add — the
    /// same order as the portable i-p-j sweep, so the portable backend
    /// must match it *bitwise*.
    #[allow(clippy::too_many_arguments)]
    fn naive_nn_acc(
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        n: usize,
        k: usize,
        acc: bool,
        skip_zeros: bool,
    ) {
        if !acc {
            c.fill(0.0);
        }
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    let av = a[i * k + p];
                    if skip_zeros && av == 0.0 {
                        continue;
                    }
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
    }

    /// Ragged-edge shape sweep: lane-misaligned k, unroll-misaligned n,
    /// odd m, plus the degenerate m=1 / n=1 / empty cases.
    fn shapes(rng: &mut crate::util::rng::Pcg) -> (usize, usize, usize) {
        match rng.range(0, 6) {
            0 => (1, rng.range(1, 20), rng.range(1, 40)),       // decode row
            1 => (rng.range(1, 20), 1, rng.range(1, 40)),       // single key
            2 => (rng.range(1, 8), rng.range(1, 8), 0),         // empty k
            3 => (0, rng.range(0, 8), rng.range(0, 16)),        // empty m
            _ => (rng.range(1, 20), rng.range(1, 20), rng.range(1, 70)),
        }
    }

    #[test]
    fn select_is_stable_and_listed() {
        let b = Backend::select();
        assert_eq!(b, Backend::select(), "detection must be cached");
        assert!(Backend::all().contains(&b));
        assert_eq!(Backend::all()[0], Backend::Portable);
    }

    #[test]
    fn nt_fixed_order_tier_is_bitwise_across_backends() {
        // Every backend must reproduce the portable bits exactly, on
        // ragged tails (k, n off the lane/unroll grid), m=1, n=1, and
        // empty blocks.
        Cases::standard(141).check(|rng| {
            let (m, n, k) = shapes(rng);
            let a: Vec<f32> = rng.gauss_vec(m * k);
            let b: Vec<f32> = rng.gauss_vec(n * k);
            let mut reference = vec![0f32; m * n];
            Backend::Portable.matmul_nt_into(&a, &b, &mut reference, m, n, k);
            // portable is allclose to the scalar oracle…
            assert_allclose(&reference, &naive_nt(&a, &b, m, n, k), 1e-4, 1e-4, "nt-oracle")?;
            // …and every other backend is *bitwise* equal to portable
            for &mk in Backend::all() {
                let mut c = vec![0f32; m * n];
                mk.matmul_nt_into(&a, &b, &mut c, m, n, k);
                if c != reference {
                    return Err(format!("{} nt diverged bitwise at m={m} n={n} k={k}", mk.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemv_and_dot_are_bitwise_across_backends() {
        Cases::standard(142).check(|rng| {
            let n = rng.range(1, 40);
            let k = rng.range(0, 70);
            let a: Vec<f32> = rng.gauss_vec(k);
            let b: Vec<f32> = rng.gauss_vec(n * k);
            let mut reference = vec![0f32; n];
            Backend::Portable.gemv_nt(&a, &b, &mut reference, n, k);
            for &mk in Backend::all() {
                let mut c = vec![0f32; n];
                mk.gemv_nt(&a, &b, &mut c, n, k);
                if c != reference {
                    return Err(format!("{} gemv diverged bitwise at n={n} k={k}", mk.name()));
                }
                // gemv ≡ per-dot, per backend (the decode≡prefill seam)
                let via_dot: Vec<f32> = (0..n).map(|j| mk.dot(&a, &b[j * k..(j + 1) * k])).collect();
                if via_dot != c {
                    return Err(format!("{} gemv != its own dot loop", mk.name()));
                }
                // and m=1 NT routes through the same bits
                let mut via_mm = vec![0f32; n];
                mk.matmul_nt_into(&a, &b, &mut via_mm, 1, n, k);
                if via_mm != c {
                    return Err(format!("{} m=1 nt != gemv", mk.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sum_rows_acc_is_bitwise_across_backends() {
        // The KPool block-mean reduction: every backend must reproduce
        // the scalar per-row `zip` sweep bitwise (each column is one
        // pure-addition chain in row order), including accumulation
        // into a non-zero `sums` and ragged d off the lane grid.
        Cases::standard(146).check(|rng| {
            let rows = rng.range(0, 20);
            let d = rng.range(1, 40);
            let x: Vec<f32> = rng.gauss_vec(rows * d);
            let init: Vec<f32> = rng.gauss_vec(d);
            let mut want = init.clone();
            for r in 0..rows {
                for (s, &v) in want.iter_mut().zip(&x[r * d..(r + 1) * d]) {
                    *s += v;
                }
            }
            for &mk in Backend::all() {
                let mut sums = init.clone();
                mk.sum_rows_acc(&x, &mut sums, rows, d);
                if sums != want {
                    return Err(format!("{} sum_rows_acc diverged at rows={rows} d={d}", mk.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn i8_kernel_is_exact_on_all_backends() {
        Cases::standard(143).check(|rng| {
            let (m, n, k) = shapes(rng);
            let a: Vec<i8> = (0..m * k).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n * k).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
            let mut want = vec![0i32; m * n];
            for i in 0..m {
                for j in 0..n {
                    want[i * n + j] =
                        (0..k).map(|p| a[i * k + p] as i32 * b[j * k + p] as i32).sum();
                }
            }
            for &mk in Backend::all() {
                let mut c = vec![0i32; m * n];
                mk.matmul_nt_i8(&a, &b, &mut c, m, n, k);
                if c != want {
                    return Err(format!("{} i8 kernel inexact at m={m} n={n} k={k}", mk.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nn_acc_oracle_tier_contract() {
        // Portable keeps the scalar oracle's bits (same order, unfused);
        // every backend stays allclose to the oracle within the stated
        // tolerance (rel/abs 1e-5) on ragged shapes, with and without
        // accumulation and zero-skipping.
        Cases::standard(144).check(|rng| {
            let (m, k, n) = shapes(rng);
            let mut a: Vec<f32> = rng.gauss_vec(m * k);
            for x in &mut a {
                if rng.chance(0.3) {
                    *x = 0.0; // exercise the skip_zeros identity
                }
            }
            let b: Vec<f32> = rng.gauss_vec(k * n);
            let init: Vec<f32> = rng.gauss_vec(m * n);
            for acc in [false, true] {
                for skip in [false, true] {
                    let mut want = init.clone();
                    naive_nn_acc(&a, &b, &mut want, m, n, k, acc, skip);
                    let mut portable = init.clone();
                    Backend::Portable.matmul_nn_acc(&a, &b, &mut portable, m, n, k, acc, skip);
                    if portable != want {
                        return Err(format!("portable nn_acc lost oracle bits (acc={acc} skip={skip})"));
                    }
                    for &mk in Backend::all() {
                        let mut c = init.clone();
                        mk.matmul_nn_acc(&a, &b, &mut c, m, n, k, acc, skip);
                        assert_allclose(
                            &c,
                            &want,
                            1e-5,
                            1e-5,
                            &format!("{} nn_acc acc={acc} skip={skip} m={m} n={n} k={k}", mk.name()),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nn_acc_skip_zeros_is_value_identical_per_backend() {
        // The zero-skip branch must be `==`-identical to the dense sweep
        // on every backend (fma(0,b,c) == c + 0·b under IEEE ==).
        Cases::standard(145).check(|rng| {
            let (m, k, n) = (rng.range(1, 10), rng.range(1, 10), rng.range(1, 10));
            let mut a: Vec<f32> = rng.gauss_vec(m * k);
            for x in &mut a {
                if rng.chance(0.4) {
                    *x = 0.0;
                }
            }
            let b: Vec<f32> = rng.gauss_vec(k * n);
            for &mk in Backend::all() {
                let mut skip = vec![0f32; m * n];
                let mut dense = vec![0f32; m * n];
                mk.matmul_nn_acc(&a, &b, &mut skip, m, n, k, false, true);
                mk.matmul_nn_acc(&a, &b, &mut dense, m, n, k, false, false);
                if skip != dense {
                    return Err(format!("{} zero-skip changed values", mk.name()));
                }
            }
            Ok(())
        });
    }
}
