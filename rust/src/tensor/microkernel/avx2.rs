//! Hand-written x86_64 AVX2(+FMA) microkernels (`core::arch` tier).
//!
//! Safety: every function here is `unsafe` with
//! `#[target_feature(enable = ...)]` — callers must have verified the
//! CPU supports AVX2 and FMA ([`super::Backend::select`] does, once per
//! process). The crate denies `unsafe_op_in_unsafe_fn`, so each body
//! wraps its intrinsic/pointer work in an explicit `unsafe {}` block
//! whose SAFETY comment states the in-bounds argument.
//!
//! Determinism: the f32 NT family (`matmul_nt_into`, `gemv_nt`, `dot`)
//! is in the **fixed-order bitwise tier** — it reproduces the portable
//! lane order exactly. One `__m256` accumulator per output takes the
//! terms at positions `p ≡ l (mod 8)` in increasing `p` using *unfused*
//! `_mm256_mul_ps` + `_mm256_add_ps` (never FMA: its single rounding
//! would change bits vs the portable two-rounding multiply-add), the 8
//! lanes are stored and summed sequentially `0..8`, and the `k % 8`
//! remainder is added scalarly in increasing `p` — Rust/LLVM never
//! contracts a scalar `a * b + c`, so the remainder matches portable
//! bit-for-bit too. `matmul_nt_i8` is exact integer arithmetic.
//! `matmul_nn_acc` is the **oracle tier**: same summation order as
//! portable, but fused (`_mm256_fmadd_ps` / `f32::mul_add`) rounding.
//! The fixed-order/fused split is machine-checked by the sparge-lint
//! `fixed-order-no-fma` rule (xtask/lint.toml).

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Sum the 8 lanes of `v` sequentially `0..8` — the same fold as
/// `[f32; 8]::iter().sum()` in the portable tier (bitwise contract).
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_seq(v: __m256) -> f32 {
    let mut buf = [0f32; 8];
    // SAFETY: `buf` is a stack array of exactly 8 f32s, matching the
    // 256-bit unaligned store.
    unsafe {
        _mm256_storeu_ps(buf.as_mut_ptr(), v);
    }
    buf.iter().sum()
}

/// Dot product; bitwise-identical to `portable::dot`.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 and `b.len() >= a.len()`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len();
    let kl = k & !7;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    // SAFETY: `p` steps in 8s below `kl <= k`, so every 8-lane load from
    // `ap`/`bp` stays inside the `k`-element slices.
    unsafe {
        let mut vacc = _mm256_setzero_ps();
        let mut p = 0;
        while p < kl {
            let va = _mm256_loadu_ps(ap.add(p));
            let vb = _mm256_loadu_ps(bp.add(p));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(va, vb));
            p += 8;
        }
        let mut s = hsum_seq(vacc);
        while p < k {
            s += a[p] * b[p];
            p += 1;
        }
        s
    }
}

/// GEMV against row-major B; bitwise-identical to `portable::gemv_nt`
/// (and hence to the per-`dot` loop — the decode≡prefill seam).
///
/// # Safety
/// Caller must ensure the CPU supports AVX2, `a.len() >= k`,
/// `b.len() >= n * k`, and `c.len() >= n`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn gemv_nt(a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    let n4 = n & !3;
    let kl = k & !7;
    let ap = a.as_ptr();
    // SAFETY: `j + 3 < n4 <= n` bounds the four row pointers inside
    // `b[.. n * k]`, and `p` steps in 8s below `kl <= k`, so every load
    // stays inside its row; the scalar remainder indexes `p < k`.
    unsafe {
        let mut j = 0;
        while j < n4 {
            let b0 = b.as_ptr().add(j * k);
            let b1 = b.as_ptr().add((j + 1) * k);
            let b2 = b.as_ptr().add((j + 2) * k);
            let b3 = b.as_ptr().add((j + 3) * k);
            let mut v0 = _mm256_setzero_ps();
            let mut v1 = _mm256_setzero_ps();
            let mut v2 = _mm256_setzero_ps();
            let mut v3 = _mm256_setzero_ps();
            let mut p = 0;
            while p < kl {
                let va = _mm256_loadu_ps(ap.add(p));
                v0 = _mm256_add_ps(v0, _mm256_mul_ps(va, _mm256_loadu_ps(b0.add(p))));
                v1 = _mm256_add_ps(v1, _mm256_mul_ps(va, _mm256_loadu_ps(b1.add(p))));
                v2 = _mm256_add_ps(v2, _mm256_mul_ps(va, _mm256_loadu_ps(b2.add(p))));
                v3 = _mm256_add_ps(v3, _mm256_mul_ps(va, _mm256_loadu_ps(b3.add(p))));
                p += 8;
            }
            let mut s = [hsum_seq(v0), hsum_seq(v1), hsum_seq(v2), hsum_seq(v3)];
            while p < k {
                let av = a[p];
                s[0] += av * *b0.add(p);
                s[1] += av * *b1.add(p);
                s[2] += av * *b2.add(p);
                s[3] += av * *b3.add(p);
                p += 1;
            }
            c[j] = s[0];
            c[j + 1] = s[1];
            c[j + 2] = s[2];
            c[j + 3] = s[3];
            j += 4;
        }
        while j < n {
            c[j] = dot(a, &b[j * k..(j + 1) * k]);
            j += 1;
        }
    }
}

/// NT kernel, 2×4 register tile; bitwise-identical to
/// `portable::matmul_nt_into`. 2 A vectors + 4 B vectors + 8
/// accumulators = 14 of the 16 ymm registers.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2, `a.len() >= m * k`,
/// `b.len() >= n * k`, and `c.len() >= m * n`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn matmul_nt_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
) {
    let n4 = n & !3;
    let kl = k & !7;
    let m2 = m & !1;
    // SAFETY: row pointers are bounded by `i + 1 < m2 <= m` and
    // `j + 3 < n4 <= n`; vector loads step `p` in 8s below `kl <= k` and
    // the scalar remainder indexes `p < k`, so every access stays inside
    // the `m*k` / `n*k` / `m*n` slices the caller guarantees.
    unsafe {
        let mut i = 0;
        while i < m2 {
            let ar0 = a.as_ptr().add(i * k);
            let ar1 = a.as_ptr().add((i + 1) * k);
            let mut j = 0;
            while j < n4 {
                let b0 = b.as_ptr().add(j * k);
                let b1 = b.as_ptr().add((j + 1) * k);
                let b2 = b.as_ptr().add((j + 2) * k);
                let b3 = b.as_ptr().add((j + 3) * k);
                let mut a00 = _mm256_setzero_ps();
                let mut a01 = _mm256_setzero_ps();
                let mut a02 = _mm256_setzero_ps();
                let mut a03 = _mm256_setzero_ps();
                let mut a10 = _mm256_setzero_ps();
                let mut a11 = _mm256_setzero_ps();
                let mut a12 = _mm256_setzero_ps();
                let mut a13 = _mm256_setzero_ps();
                let mut p = 0;
                while p < kl {
                    let va0 = _mm256_loadu_ps(ar0.add(p));
                    let va1 = _mm256_loadu_ps(ar1.add(p));
                    let vb0 = _mm256_loadu_ps(b0.add(p));
                    let vb1 = _mm256_loadu_ps(b1.add(p));
                    let vb2 = _mm256_loadu_ps(b2.add(p));
                    let vb3 = _mm256_loadu_ps(b3.add(p));
                    a00 = _mm256_add_ps(a00, _mm256_mul_ps(va0, vb0));
                    a01 = _mm256_add_ps(a01, _mm256_mul_ps(va0, vb1));
                    a02 = _mm256_add_ps(a02, _mm256_mul_ps(va0, vb2));
                    a03 = _mm256_add_ps(a03, _mm256_mul_ps(va0, vb3));
                    a10 = _mm256_add_ps(a10, _mm256_mul_ps(va1, vb0));
                    a11 = _mm256_add_ps(a11, _mm256_mul_ps(va1, vb1));
                    a12 = _mm256_add_ps(a12, _mm256_mul_ps(va1, vb2));
                    a13 = _mm256_add_ps(a13, _mm256_mul_ps(va1, vb3));
                    p += 8;
                }
                let mut s = [
                    hsum_seq(a00),
                    hsum_seq(a01),
                    hsum_seq(a02),
                    hsum_seq(a03),
                    hsum_seq(a10),
                    hsum_seq(a11),
                    hsum_seq(a12),
                    hsum_seq(a13),
                ];
                while p < k {
                    let av0 = *ar0.add(p);
                    let av1 = *ar1.add(p);
                    s[0] += av0 * *b0.add(p);
                    s[1] += av0 * *b1.add(p);
                    s[2] += av0 * *b2.add(p);
                    s[3] += av0 * *b3.add(p);
                    s[4] += av1 * *b0.add(p);
                    s[5] += av1 * *b1.add(p);
                    s[6] += av1 * *b2.add(p);
                    s[7] += av1 * *b3.add(p);
                    p += 1;
                }
                c[i * n + j] = s[0];
                c[i * n + j + 1] = s[1];
                c[i * n + j + 2] = s[2];
                c[i * n + j + 3] = s[3];
                c[(i + 1) * n + j] = s[4];
                c[(i + 1) * n + j + 1] = s[5];
                c[(i + 1) * n + j + 2] = s[6];
                c[(i + 1) * n + j + 3] = s[7];
                j += 4;
            }
            while j < n {
                let br = &b[j * k..(j + 1) * k];
                c[i * n + j] = dot(&a[i * k..(i + 1) * k], br);
                c[(i + 1) * n + j] = dot(&a[(i + 1) * k..(i + 2) * k], br);
                j += 1;
            }
            i += 2;
        }
        while i < m {
            gemv_nt(&a[i * k..(i + 1) * k], b, &mut c[i * n..(i + 1) * n], n, k);
            i += 1;
        }
    }
}

/// Column-wise row accumulate (`sums[j] += x[r][j]` in increasing `r`);
/// bitwise-identical to `portable::sum_rows_acc`: each column is an
/// independent pure-addition chain in row order, and `_mm256_add_ps`
/// evaluates the eight column chains of a lane group element-wise with
/// no cross-lane reduction, so lane width cannot change any bit.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2, `x.len() >= rows * d`, and
/// `sums.len() >= d`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sum_rows_acc(x: &[f32], sums: &mut [f32], rows: usize, d: usize) {
    let dl = d & !7;
    // SAFETY: `r < rows` bounds each row pointer inside `x[.. rows * d]`;
    // vector loads/stores step `j` in 8s below `dl <= d` and the scalar
    // remainder indexes `j < d`, so every access stays inside the slices
    // the caller guarantees.
    unsafe {
        let sp = sums.as_mut_ptr();
        for r in 0..rows {
            let rp = x.as_ptr().add(r * d);
            let mut j = 0;
            while j < dl {
                let vs = _mm256_loadu_ps(sp.add(j));
                let vx = _mm256_loadu_ps(rp.add(j));
                _mm256_storeu_ps(sp.add(j), _mm256_add_ps(vs, vx));
                j += 8;
            }
            while j < d {
                *sp.add(j) += *rp.add(j);
                j += 1;
            }
        }
    }
}

/// int8 NT kernel: sign-extend 16 i8 lanes to i16, `_mm256_madd_epi16`
/// pairs into 8 i32 lanes (|product| ≤ 127² = 16129, so the pairwise i32
/// add can never overflow), accumulate with `_mm256_add_epi32`. Exact
/// integer arithmetic — bitwise by construction, any order.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2, `a.len() >= m * k`,
/// `b.len() >= n * k`, and `c.len() >= m * n`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn matmul_nt_i8(a: &[i8], b: &[i8], c: &mut [i32], m: usize, n: usize, k: usize) {
    let n4 = n & !3;
    let k16 = k & !15;
    // SAFETY: row pointers are bounded by `i < m` and `j + 3 < n4 <= n`;
    // the 128-bit loads step `p` in 16s below `k16 <= k` and the scalar
    // remainder indexes `p < k`, so every access stays inside the
    // `m*k` / `n*k` slices the caller guarantees.
    unsafe {
        for i in 0..m {
            let ar = a.as_ptr().add(i * k);
            let mut j = 0;
            while j < n4 {
                let b0 = b.as_ptr().add(j * k);
                let b1 = b.as_ptr().add((j + 1) * k);
                let b2 = b.as_ptr().add((j + 2) * k);
                let b3 = b.as_ptr().add((j + 3) * k);
                let mut v0 = _mm256_setzero_si256();
                let mut v1 = _mm256_setzero_si256();
                let mut v2 = _mm256_setzero_si256();
                let mut v3 = _mm256_setzero_si256();
                let mut p = 0;
                while p < k16 {
                    // one 16-lane A chunk feeds all four B rows
                    let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(ar.add(p) as *const __m128i));
                    let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b0.add(p) as *const __m128i));
                    let w1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b1.add(p) as *const __m128i));
                    let w2 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b2.add(p) as *const __m128i));
                    let w3 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b3.add(p) as *const __m128i));
                    v0 = _mm256_add_epi32(v0, _mm256_madd_epi16(va, w0));
                    v1 = _mm256_add_epi32(v1, _mm256_madd_epi16(va, w1));
                    v2 = _mm256_add_epi32(v2, _mm256_madd_epi16(va, w2));
                    v3 = _mm256_add_epi32(v3, _mm256_madd_epi16(va, w3));
                    p += 16;
                }
                let mut buf = [0i32; 8];
                _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, v0);
                let mut s0: i32 = buf.iter().sum();
                _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, v1);
                let mut s1: i32 = buf.iter().sum();
                _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, v2);
                let mut s2: i32 = buf.iter().sum();
                _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, v3);
                let mut s3: i32 = buf.iter().sum();
                while p < k {
                    let av = *ar.add(p) as i32;
                    s0 += av * *b0.add(p) as i32;
                    s1 += av * *b1.add(p) as i32;
                    s2 += av * *b2.add(p) as i32;
                    s3 += av * *b3.add(p) as i32;
                    p += 1;
                }
                c[i * n + j] = s0;
                c[i * n + j + 1] = s1;
                c[i * n + j + 2] = s2;
                c[i * n + j + 3] = s3;
                j += 4;
            }
            while j < n {
                let br = b.as_ptr().add(j * k);
                let mut s = 0i32;
                for p in 0..k {
                    s += *ar.add(p) as i32 * *br.add(p) as i32;
                }
                c[i * n + j] = s;
                j += 1;
            }
        }
    }
}

/// NN-accumulate (P̃·V): broadcast `a[i][p]`, fused AXPY over B row `p`.
/// **Oracle tier** — same i-p-j summation order as portable, but
/// `_mm256_fmadd_ps` / `f32::mul_add` fuse the rounding, so results are
/// allclose (not bitwise) vs the portable/scalar reference. The
/// `skip_zeros` early-out stays value-identical: `fma(0, b, c) == c + 0·b`
/// under IEEE `==`.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 and FMA, `a.len() >= m * k`,
/// `b.len() >= k * n`, and `c.len() >= m * n`.
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn matmul_nn_acc(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    acc: bool,
    skip_zeros: bool,
) {
    if !acc {
        c.fill(0.0);
    }
    let nl = n & !7;
    // SAFETY: `cr`/`br` are bounded by `i < m` and `p < k`; vector
    // loads/stores step `j` in 8s below `nl <= n` and the scalar
    // remainder indexes `j < n`, so every access stays inside the
    // `m*k` / `k*n` / `m*n` slices the caller guarantees.
    unsafe {
        for i in 0..m {
            let cr = c.as_mut_ptr().add(i * n);
            for p in 0..k {
                let av = a[i * k + p];
                if skip_zeros && av == 0.0 {
                    continue;
                }
                let br = b.as_ptr().add(p * n);
                let va = _mm256_set1_ps(av);
                let mut j = 0;
                while j < nl {
                    let vc = _mm256_loadu_ps(cr.add(j));
                    let vb = _mm256_loadu_ps(br.add(j));
                    _mm256_storeu_ps(cr.add(j), _mm256_fmadd_ps(va, vb, vc));
                    j += 8;
                }
                while j < n {
                    *cr.add(j) = av.mul_add(*br.add(j), *cr.add(j));
                    j += 1;
                }
            }
        }
    }
}
