//! SageAttention-style per-block INT8 quantization (paper §3.5 /
//! Alg. 1 lines 3 & 12).
//!
//! Q and K blocks are quantized symmetrically to int8 with a per-block
//! scale δ = absmax/127; the QKᵀ product is accumulated in i32 and
//! dequantized with δ_Q·δ_K. Following SageAttention, K is *smoothed*
//! first: the per-channel mean of K across tokens is subtracted before
//! quantization. Softmax is shift-invariant per row, because
//! Q_i · mean_kᵀ is constant across j within a row — so smoothing changes
//! no attention output while shrinking K's quantization range.

use super::Tensor;

/// An int8-quantized block with its dequantization scale.
#[derive(Clone, Debug)]
pub struct QuantBlock {
    /// Row-major int8 payload, shape (rows, d).
    pub data: Vec<i8>,
    pub rows: usize,
    pub d: usize,
    /// Dequant scale: f32 value ≈ data * scale.
    pub scale: f32,
}

impl QuantBlock {
    /// Quantize a (rows, d) f32 slice symmetrically to int8.
    pub fn quantize(block: &[f32], rows: usize, d: usize) -> QuantBlock {
        debug_assert_eq!(block.len(), rows * d);
        let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = if absmax == 0.0 { 1.0 / 127.0 } else { absmax / 127.0 };
        let inv = 1.0 / scale;
        let data = block.iter().map(|&x| (x * inv).round().clamp(-127.0, 127.0) as i8).collect();
        QuantBlock { data, rows, d, scale }
    }

    /// Dequantize back to f32 (tests / debugging).
    pub fn dequantize(&self) -> Vec<f32> {
        self.data.iter().map(|&q| q as f32 * self.scale).collect()
    }

    /// Re-quantize this block in place from fresh f32 data, reusing the
    /// int8 payload's allocation (the KV-cache tail-block requantize and
    /// per-step Q staging paths — allocation-free once the payload has
    /// reached its high-water size). Produces byte-identical payload and
    /// scale to [`QuantBlock::quantize`] of the same data.
    pub fn requantize(&mut self, block: &[f32], rows: usize, d: usize) {
        debug_assert_eq!(block.len(), rows * d);
        let absmax = block.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = if absmax == 0.0 { 1.0 / 127.0 } else { absmax / 127.0 };
        let inv = 1.0 / scale;
        self.data.clear();
        self.data.extend(block.iter().map(|&x| (x * inv).round().clamp(-127.0, 127.0) as i8));
        self.rows = rows;
        self.d = d;
        self.scale = scale;
    }
}

/// Per-channel mean of a (n, d) tensor across rows — the K-smoothing vector.
pub fn channel_mean(x: &Tensor) -> Vec<f32> {
    super::ops::mean_axis0(x)
}

/// Subtract a channel vector from every row (K smoothing).
pub fn smooth(x: &Tensor, mean: &[f32]) -> Tensor {
    assert_eq!(x.ndim(), 2);
    assert_eq!(x.dim(1), mean.len());
    let mut out = x.clone();
    let d = mean.len();
    for i in 0..out.dim(0) {
        let row = &mut out.data_mut()[i * d..(i + 1) * d];
        for (v, &m) in row.iter_mut().zip(mean) {
            *v -= m;
        }
    }
    out
}

/// Quantize a full (N, d) matrix into blocks of `block_rows` rows.
/// The final block may be shorter.
pub fn quantize_blocks(x: &Tensor, block_rows: usize) -> Vec<QuantBlock> {
    assert_eq!(x.ndim(), 2);
    let (n, d) = (x.dim(0), x.dim(1));
    let mut out = Vec::with_capacity(n.div_ceil(block_rows));
    let mut r = 0;
    while r < n {
        let r1 = (r + block_rows).min(n);
        out.push(QuantBlock::quantize(&x.data()[r * d..r1 * d], r1 - r, d));
        r = r1;
    }
    out
}

/// Re-quantize `x` into `out` blockwise, reusing `out`'s blocks (and
/// their int8 payload allocations) where they exist — value-identical to
/// `*out = quantize_blocks(x, block_rows)` without the per-call
/// allocations once `out` has reached its high-water block count. The
/// per-call Q staging of the attention decode path.
pub fn quantize_blocks_into(x: &Tensor, block_rows: usize, out: &mut Vec<QuantBlock>) {
    assert_eq!(x.ndim(), 2);
    let (n, d) = (x.dim(0), x.dim(1));
    let nb = n.div_ceil(block_rows);
    out.truncate(nb);
    for (b, blk) in out.iter_mut().enumerate() {
        let r0 = b * block_rows;
        let r1 = (r0 + block_rows).min(n);
        blk.requantize(&x.data()[r0 * d..r1 * d], r1 - r0, d);
    }
    for b in out.len()..nb {
        let r0 = b * block_rows;
        let r1 = (r0 + block_rows).min(n);
        out.push(QuantBlock::quantize(&x.data()[r0 * d..r1 * d], r1 - r0, d));
    }
}

/// Dequantized QKᵀ for a pair of quantized blocks:
/// S[i][j] = (Σ_p q[i][p]·k[j][p]) · δ_Q·δ_K · scale_extra.
pub fn qk_dequant(q: &QuantBlock, k: &QuantBlock, scale_extra: f32, out: &mut [f32]) {
    let mut acc = Vec::new();
    qk_dequant_scratch(q, k, scale_extra, out, &mut acc);
}

/// [`qk_dequant`] with a caller-provided i32 accumulator (a
/// [`crate::util::threadpool::Workspace`] buffer on the hot path), so the
/// INT8 score path allocates nothing per visited block.
pub fn qk_dequant_scratch(
    q: &QuantBlock,
    k: &QuantBlock,
    scale_extra: f32,
    out: &mut [f32],
    acc: &mut Vec<i32>,
) {
    qk_dequant_scratch_with(super::microkernel::Backend::select(), q, k, scale_extra, out, acc);
}

/// [`qk_dequant_scratch`] on an explicit microkernel backend. The i8
/// kernel is exact integer arithmetic and the dequant multiply is
/// elementwise, so every backend produces identical bits.
pub fn qk_dequant_scratch_with(
    mk: super::microkernel::Backend,
    q: &QuantBlock,
    k: &QuantBlock,
    scale_extra: f32,
    out: &mut [f32],
    acc: &mut Vec<i32>,
) {
    debug_assert_eq!(q.d, k.d);
    debug_assert_eq!(out.len(), q.rows * k.rows);
    acc.clear();
    acc.resize(q.rows * k.rows, 0);
    mk.matmul_nt_i8(&q.data, &k.data, acc, q.rows, k.rows, q.d);
    let s = q.scale * k.scale * scale_extra;
    for (o, &a) in out.iter_mut().zip(acc.iter()) {
        *o = a as f32 * s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{rel_l1, Cases};
    use crate::util::rng::Pcg;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        Cases::standard(301).check(|rng| {
            let rows = rng.range(1, 65);
            let d = rng.range(1, 129);
            let x: Vec<f32> = rng.gauss_vec(rows * d);
            let qb = QuantBlock::quantize(&x, rows, d);
            let y = qb.dequantize();
            let absmax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
            let step = absmax / 127.0;
            for (&xi, &yi) in x.iter().zip(&y) {
                if (xi - yi).abs() > step * 0.5 + 1e-6 {
                    return Err(format!("roundtrip error {} > half-step {}", (xi - yi).abs(), step / 2.0));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_block_quantizes_to_zero() {
        let qb = QuantBlock::quantize(&[0.0; 8], 2, 4);
        assert!(qb.data.iter().all(|&q| q == 0));
        assert!(qb.dequantize().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn qk_dequant_close_to_f32() {
        let mut rng = Pcg::seeded(9);
        let d = 64;
        let q = Tensor::randn(&[16, d], &mut rng);
        let k = Tensor::randn(&[16, d], &mut rng);
        let exact = crate::tensor::matmul::matmul_nt(&q, &k);
        let qq = QuantBlock::quantize(q.data(), 16, d);
        let qk = QuantBlock::quantize(k.data(), 16, d);
        let mut approx = vec![0f32; 16 * 16];
        qk_dequant(&qq, &qk, 1.0, &mut approx);
        let err = rel_l1(&approx, exact.data());
        assert!(err < 0.02, "int8 rel-L1 {err}");
    }

    #[test]
    fn requantize_reuses_payload_and_matches_fresh_quantize() {
        Cases::standard(302).check(|rng| {
            let rows = rng.range(1, 33);
            let d = rng.range(1, 65);
            let warm: Vec<f32> = rng.gauss_vec(rows * d);
            let x: Vec<f32> = rng.gauss_vec(rows * d);
            let mut qb = QuantBlock::quantize(&warm, rows, d);
            let cap = qb.data.capacity();
            qb.requantize(&x, rows, d);
            let fresh = QuantBlock::quantize(&x, rows, d);
            if qb.data != fresh.data || qb.scale != fresh.scale || qb.rows != fresh.rows {
                return Err("in-place requantize diverged from fresh quantize".into());
            }
            if qb.data.capacity() != cap {
                return Err("same-size requantize must reuse the payload allocation".into());
            }
            Ok(())
        });
    }

    #[test]
    fn quantize_blocks_into_matches_fresh() {
        let mut rng = Pcg::seeded(15);
        let a = Tensor::randn(&[50, 8], &mut rng);
        let b = Tensor::randn(&[70, 8], &mut rng);
        let mut staged = Vec::new();
        quantize_blocks_into(&a, 16, &mut staged); // warm with a different shape
        quantize_blocks_into(&b, 16, &mut staged);
        let fresh = quantize_blocks(&b, 16);
        assert_eq!(staged.len(), fresh.len());
        for (s, f) in staged.iter().zip(&fresh) {
            assert_eq!(s.data, f.data);
            assert_eq!(s.scale, f.scale);
            assert_eq!((s.rows, s.d), (f.rows, f.d));
        }
    }

    #[test]
    fn smoothing_reduces_k_range() {
        // K rows share a large common offset; smoothing should strip it.
        let mut rng = Pcg::seeded(11);
        let d = 32;
        let mut k = Tensor::randn(&[64, d], &mut rng);
        for i in 0..64 {
            for v in k.row_mut(i) {
                *v += 10.0;
            }
        }
        let mean = channel_mean(&k);
        let ks = smooth(&k, &mean);
        assert!(ks.abs_max() < k.abs_max() / 2.0);
    }

    #[test]
    fn quantize_blocks_partitions_rows() {
        let mut rng = Pcg::seeded(13);
        let x = Tensor::randn(&[100, 8], &mut rng);
        let blocks = quantize_blocks(&x, 32);
        assert_eq!(blocks.len(), 4);
        assert_eq!(blocks[3].rows, 4);
        let total: usize = blocks.iter().map(|b| b.rows).sum();
        assert_eq!(total, 100);
    }
}
