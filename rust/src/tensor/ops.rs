//! Row-wise tensor operations used across the attention engines.

use super::Tensor;

/// Row-wise softmax of a 2-D tensor (numerically stable).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 2);
    let (r, c) = (x.dim(0), x.dim(1));
    let mut out = Tensor::zeros(&[r, c]);
    for i in 0..r {
        let row = x.row(i);
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let orow = out.row_mut(i);
        if m == f32::NEG_INFINITY {
            // all-masked row: softmax of all -inf is defined here as zeros
            // (matches the masked-attention convention: contributes nothing).
            continue;
        }
        let mut sum = 0f32;
        for (o, &v) in orow.iter_mut().zip(row) {
            let e = (v - m).exp();
            *o = e;
            sum += e;
        }
        if sum > 0.0 {
            let inv = 1.0 / sum;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    }
    out
}

/// Row maxima of a 2-D tensor.
pub fn rowmax(x: &Tensor) -> Vec<f32> {
    assert_eq!(x.ndim(), 2);
    (0..x.dim(0)).map(|i| x.row(i).iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))).collect()
}

/// Row sums of a 2-D tensor.
pub fn rowsum(x: &Tensor) -> Vec<f32> {
    assert_eq!(x.ndim(), 2);
    (0..x.dim(0)).map(|i| x.row(i).iter().sum()).collect()
}

/// Mean across rows: (r,c) -> (c,). This is the paper's block→token
/// compression `mean(Q_i, axis=0)`.
pub fn mean_axis0(x: &Tensor) -> Vec<f32> {
    assert_eq!(x.ndim(), 2);
    let (r, c) = (x.dim(0), x.dim(1));
    let mut out = vec![0f32; c];
    for i in 0..r {
        for (o, &v) in out.iter_mut().zip(x.row(i)) {
            *o += v;
        }
    }
    let inv = 1.0 / r as f32;
    for o in &mut out {
        *o *= inv;
    }
    out
}

/// L2 norm of a slice.
pub fn norm(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum::<f32>().sqrt()
}

/// Numerically-stable log-sum-exp of a slice.
pub fn logsumexp(x: &[f32]) -> f32 {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    if m == f32::NEG_INFINITY {
        return f32::NEG_INFINITY;
    }
    m + x.iter().map(|&v| (v - m).exp()).sum::<f32>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, Cases};

    #[test]
    fn softmax_rows_sum_to_one() {
        Cases::standard(201).check(|rng| {
            let r = rng.range(1, 9);
            let c = rng.range(1, 33);
            let x = Tensor::randn(&[r, c], rng);
            let p = softmax_rows(&x);
            for i in 0..r {
                let s: f32 = p.row(i).iter().sum();
                if (s - 1.0).abs() > 1e-5 {
                    return Err(format!("row {i} sums to {s}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn softmax_shift_invariance() {
        let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let mut y = x.clone();
        y.data_mut().iter_mut().for_each(|v| *v += 100.0);
        assert_allclose(softmax_rows(&x).data(), softmax_rows(&y).data(), 1e-6, 0.0, "shift").unwrap();
    }

    #[test]
    fn softmax_all_masked_row_is_zero() {
        let x = Tensor::from_vec(&[1, 2], vec![f32::NEG_INFINITY, f32::NEG_INFINITY]);
        let p = softmax_rows(&x);
        assert_eq!(p.data(), &[0.0, 0.0]);
    }

    #[test]
    fn row_reductions() {
        let x = Tensor::from_vec(&[2, 3], vec![1., 5., 3., -1., -2., -3.]);
        assert_eq!(rowmax(&x), vec![5.0, -1.0]);
        assert_eq!(rowsum(&x), vec![9.0, -6.0]);
        assert_eq!(mean_axis0(&x), vec![0.0, 1.5, 0.0]);
    }

    #[test]
    fn logsumexp_matches_direct() {
        let xs = [0.1f32, 0.7, -0.3];
        let direct = xs.iter().map(|v| v.exp()).sum::<f32>().ln();
        assert!((logsumexp(&xs) - direct).abs() < 1e-6);
        assert_eq!(logsumexp(&[f32::NEG_INFINITY]), f32::NEG_INFINITY);
    }

    #[test]
    fn norm_basic() {
        assert_eq!(norm(&[3.0, 4.0]), 5.0);
    }
}
