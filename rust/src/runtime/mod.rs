//! Runtime: load AOT HLO-text artifacts and execute them on the PJRT CPU
//! client from the L3 hot path. Python never runs here — artifacts are
//! produced once by `make artifacts` (python/compile/aot.py).

pub mod artifacts;
pub mod executor;

pub use artifacts::{Artifact, IoSpec, Manifest};
pub use executor::{Executor, Runtime, Value};
