//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. `manifest.json` describes every exported HLO module —
//! path, input/output shapes+dtypes, and build-time metadata (baked
//! hyper-parameters, model geometry).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Dtype of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Shape + dtype of one artifact input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<IoSpec> {
        let shape = j
            .get("shape")
            .and_then(|v| v.as_arr())
            .context("iospec: shape")?
            .iter()
            .map(|d| d.as_usize().context("iospec: dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(j.get("dtype").and_then(|v| v.as_str()).context("iospec: dtype")?)?;
        Ok(IoSpec { shape, dtype })
    }
}

/// One exported HLO module.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: BTreeMap<String, Json>,
}

impl Artifact {
    /// Metadata number (e.g. baked τ) if present.
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|v| v.as_f64())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }
}

/// The full artifact registry.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let version = j.get("version").and_then(|v| v.as_usize()).context("manifest: version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let arts = j.get("artifacts").context("manifest: artifacts")?;
        let Json::Obj(pairs) = arts else { bail!("manifest: artifacts must be an object") };
        let mut artifacts = BTreeMap::new();
        for (name, aj) in pairs {
            let rel = aj.get("path").and_then(|v| v.as_str()).context("artifact: path")?;
            let inputs = aj
                .get("inputs")
                .and_then(|v| v.as_arr())
                .context("artifact: inputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = aj
                .get("outputs")
                .and_then(|v| v.as_arr())
                .context("artifact: outputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let meta = aj.get("meta").and_then(|v| v.as_map()).unwrap_or_default();
            artifacts.insert(
                name.clone(),
                Artifact { name: name.clone(), path: dir.join(rel), inputs, outputs, meta },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Default location: `$SPARGE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SPARGE_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        let available = self.artifacts.len();
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest ({available} available)"))
    }

    /// All artifacts whose meta `kind` matches.
    pub fn by_kind(&self, kind: &str) -> Vec<&Artifact> {
        self.artifacts.values().filter(|a| a.meta_str("kind") == Some(kind)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("sparge_manifest_test1");
        write_manifest(
            &dir,
            r#"{"version":1,"artifacts":{"toy":{"path":"toy.hlo.txt",
                "inputs":[{"shape":[4],"dtype":"f32"}],
                "outputs":[{"shape":[4],"dtype":"f32"}],
                "meta":{"kind":"toy","tau":0.95}}}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("toy").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4]);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.meta_f64("tau"), Some(0.95));
        assert_eq!(m.by_kind("toy").len(), 1);
        assert!(m.get("missing").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("sparge_manifest_test2");
        write_manifest(&dir, r#"{"version":99,"artifacts":{}}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let dir = std::env::temp_dir().join("sparge_manifest_test3");
        write_manifest(
            &dir,
            r#"{"version":1,"artifacts":{"x":{"path":"x","inputs":[{"shape":[1],"dtype":"f64"}],"outputs":[]}}}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn iospec_elements() {
        let s = IoSpec { shape: vec![2, 3, 4], dtype: Dtype::F32 };
        assert_eq!(s.elements(), 24);
        let scalar = IoSpec { shape: vec![], dtype: Dtype::F32 };
        assert_eq!(scalar.elements(), 1);
    }
}
