//! PJRT executor: compile HLO-text artifacts once, execute many times.
//!
//! Wraps the `xla` crate exactly like /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Executables hold raw PJRT pointers (not
//! `Send`), so the coordinator owns a `Runtime` on a dedicated engine
//! thread (see `crate::coordinator::engine`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::artifacts::{Artifact, Dtype, Manifest};
use crate::tensor::Tensor;

/// A typed host value crossing the runtime boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(vec![x], vec![])
    }

    pub fn from_tensor(t: &Tensor) -> Value {
        Value::F32(t.data().to_vec(), t.shape().to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(_, s) | Value::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(..) => Dtype::F32,
            Value::I32(..) => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => bail!("value is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(d, _) => Ok(d),
            _ => bail!("value is not i32"),
        }
    }

    pub fn to_tensor(&self) -> Result<Tensor> {
        let d = self.as_f32()?;
        Ok(Tensor::from_vec(self.shape(), d.to_vec()))
    }

    /// First element as f64 (scalar outputs).
    pub fn scalar(&self) -> Result<f64> {
        match self {
            Value::F32(d, _) => Ok(*d.first().context("empty value")? as f64),
            Value::I32(d, _) => Ok(*d.first().context("empty value")? as f64),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(data, shape) => {
                if shape.is_empty() {
                    xla::Literal::from(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            }
            Value::I32(data, shape) => {
                if shape.is_empty() {
                    xla::Literal::from(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, dtype: Dtype, shape: &[usize]) -> Result<Value> {
        Ok(match dtype {
            Dtype::F32 => Value::F32(lit.to_vec::<f32>()?, shape.to_vec()),
            Dtype::I32 => Value::I32(lit.to_vec::<i32>()?, shape.to_vec()),
        })
    }
}

/// A compiled artifact ready to execute.
pub struct Executor {
    pub artifact: Artifact,
    exe: Rc<xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Execute with shape/dtype validation against the manifest.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.artifact.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                self.artifact.name,
                self.artifact.inputs.len(),
                inputs.len()
            );
        }
        for (i, (v, spec)) in inputs.iter().zip(&self.artifact.inputs).enumerate() {
            if v.shape() != spec.shape.as_slice() || v.dtype() != spec.dtype {
                bail!(
                    "artifact '{}' input {i}: expected {:?} {:?}, got {:?} {:?}",
                    self.artifact.name,
                    spec.dtype,
                    spec.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = tuple.to_tuple()?;
        if parts.len() != self.artifact.outputs.len() {
            bail!(
                "artifact '{}': {} outputs in tuple, manifest says {}",
                self.artifact.name,
                parts.len(),
                self.artifact.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.artifact.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec.dtype, &spec.shape))
            .collect()
    }
}

/// The PJRT runtime: one CPU client + a compile cache over the manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create from an artifact directory (must contain manifest.json).
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        crate::log_info!(
            "PJRT client up: platform={} devices={} ({} artifacts)",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Create from the default artifact dir ($SPARGE_ARTIFACTS or ./artifacts).
    pub fn from_default_dir() -> Result<Runtime> {
        Self::new(&Manifest::default_dir())
    }

    /// Artifact directory in use.
    pub fn dir(&self) -> &PathBuf {
        &self.manifest.dir
    }

    /// Get (compiling and caching on first use) an executor for `name`.
    pub fn executor(&self, name: &str) -> Result<Executor> {
        let artifact = self.manifest.get(name)?.clone();
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Executor { artifact, exe: Rc::clone(exe) });
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            artifact.path.to_str().context("artifact path not utf-8")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        crate::log_info!("compiled '{name}' in {:.2}s", t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(Executor { artifact, exe })
    }

    /// Run an artifact by name in one call.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.executor(name)?.run(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let v = Value::F32(vec![1.0, 2.0], vec![2]);
        assert_eq!(v.shape(), &[2]);
        assert_eq!(v.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(v.as_i32().is_err());
        assert_eq!(v.scalar().unwrap(), 1.0);
        let s = Value::scalar_f32(3.5);
        assert!(s.shape().is_empty());
        let t = Tensor::from_vec(&[1, 2], vec![5.0, 6.0]);
        let vt = Value::from_tensor(&t);
        assert_eq!(vt.to_tensor().unwrap(), t);
    }

    // PJRT-backed tests live in rust/tests/runtime_integration.rs (they
    // need built artifacts); here we only check the host-side plumbing.
}
