//! Cost model: operation counting and the paper's TOPS speed metric.
//!
//! The paper defines `TOPS = O(attn) / t` where `O(attn)` is the op count
//! of a *standard* attention on the same inputs and `t` the measured
//! latency including mask prediction (§4.1) — so sparse methods earn
//! higher TOPS only by genuinely finishing sooner. We report two views:
//!
//! - **measured TOPS** from Rust wall-clock (CPU; absolute values are far
//!   below GPU numbers, comparisons across methods are meaningful);
//! - **GPU-translated TOPS**: measured skip ratios + prediction overhead
//!   folded into the paper's full-attention baseline speed, isolating the
//!   algorithmic effect from the substrate (used for Table 1's shape).

use crate::attention::types::SkipStats;

/// Op count of one standard (dense) attention head: QKᵀ + P̃V, 2 FLOPs per
/// MAC.
pub fn attention_ops(n_q: usize, n_k: usize, d: usize, causal: bool) -> f64 {
    let pairs = if causal {
        // lower-triangle token pairs (incl. diagonal)
        (n_q.min(n_k) as f64 * (n_q.min(n_k) as f64 + 1.0)) / 2.0
            + (n_q.saturating_sub(n_k) as f64) * n_k as f64
    } else {
        n_q as f64 * n_k as f64
    };
    // QK^T: pairs*d MACs; PV: pairs*d MACs; 2 FLOPs per MAC
    2.0 * 2.0 * pairs * d as f64
}

/// TOPS (tera-ops/sec) given op count and seconds.
pub fn tops(ops: f64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    ops / seconds / 1e12
}

/// The paper's reference full-attention speed on its testbed (RTX4090,
/// Table 1: 156.9–166 TOPS). Used by the GPU-translated view.
pub const PAPER_FULL_ATTENTION_TOPS: f64 = 160.0;

/// Fraction of dense attention time a sparse run would take on the paper's
/// GPU: compute scales with (1 − sparsity), plus prediction overhead as a
/// fraction of dense time (Table 3 shape).
pub fn gpu_translated_time_fraction(stats: &SkipStats, predict_overhead: f64) -> f64 {
    (1.0 - stats.sparsity()) + predict_overhead
}

/// GPU-translated TOPS for a sparse method (see module docs).
pub fn gpu_translated_tops(stats: &SkipStats, predict_overhead: f64) -> f64 {
    PAPER_FULL_ATTENTION_TOPS / gpu_translated_time_fraction(stats, predict_overhead)
}

/// Roofline-style estimate of L1 (Pallas/TPU) block residency: bytes of
/// VMEM needed per grid step for the kernel's BlockSpec (DESIGN.md §8).
pub fn vmem_bytes(bq: usize, bk: usize, d: usize, bytes_per_el: usize) -> usize {
    // Q tile + one K block + one V block + P̃ scratch + O accumulator
    (bq * d + 2 * (bk * d) + bq * bk + bq * d) * bytes_per_el
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_ops_formula() {
        // n=nk=2, d=1: 4 pairs * 2 matmuls * 2 flops = 16
        assert_eq!(attention_ops(2, 2, 1, false), 16.0);
        // causal 2x2: 3 pairs
        assert_eq!(attention_ops(2, 2, 1, true), 12.0);
    }

    #[test]
    fn tops_basic() {
        assert!((tops(2e12, 1.0) - 2.0).abs() < 1e-12);
        assert_eq!(tops(1.0, 0.0), 0.0);
    }

    #[test]
    fn translated_speed_increases_with_sparsity() {
        let dense = SkipStats { qk_total: 100, pv_total: 100, ..Default::default() };
        let mut sparse = dense;
        sparse.qk_skipped = 50;
        sparse.pv_skipped = 50;
        let t_dense = gpu_translated_tops(&dense, 0.0);
        let t_sparse = gpu_translated_tops(&sparse, 0.01);
        assert!((t_dense - PAPER_FULL_ATTENTION_TOPS).abs() < 1e-9);
        assert!(t_sparse > t_dense * 1.8, "sparse {t_sparse} dense {t_dense}");
    }

    #[test]
    fn vmem_fits_budget_for_paper_blocks() {
        // paper blocks (128, 64) at d=128, bf16: must be far below 16 MiB
        let bytes = vmem_bytes(128, 64, 128, 2);
        assert!(bytes < 16 * 1024 * 1024 / 8, "VMEM {bytes}");
    }
}
