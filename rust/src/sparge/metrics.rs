//! Accuracy and sparsity metrics (paper §3.6 & §4.1).

use crate::tensor::Tensor;

use super::predict::compress_blocks;

/// Relative L1 distance `Σ|O−O′| / Σ|O|` — the paper's attention-accuracy
/// metric, with `reference` as O.
pub fn rel_l1(candidate: &Tensor, reference: &Tensor) -> f64 {
    crate::util::prop::rel_l1(candidate.data(), reference.data())
}

/// Average block self-similarity of an (N, d) tensor under `block_rows`
/// blocking — the Sim-q / Sim-k columns of Table 4.
pub fn avg_block_similarity(x: &Tensor, block_rows: usize) -> f64 {
    let (_, sims) = compress_blocks(x, block_rows);
    crate::util::stats::mean_f32(&sims)
}

/// PSNR between two tensors (used as the image/video fidelity proxy in the
/// Table 1 reproduction; higher is better).
pub fn psnr(candidate: &Tensor, reference: &Tensor) -> f64 {
    assert_eq!(candidate.len(), reference.len());
    let mse: f64 = candidate
        .data()
        .iter()
        .zip(reference.data())
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / candidate.len() as f64;
    let peak: f64 = reference.data().iter().fold(0f32, |m, &x| m.max(x.abs())) as f64;
    if mse == 0.0 {
        return f64::INFINITY;
    }
    if peak == 0.0 {
        return 0.0;
    }
    10.0 * ((peak * peak) / mse).log10()
}

/// Cosine similarity between two flattened tensors (CLIP-style alignment
/// proxy for Table 1's CLIPSIM column).
pub fn cosine(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.len(), b.len());
    let dot: f64 = a.data().iter().zip(b.data()).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
    let na: f64 = a.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    let nb: f64 = b.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn rel_l1_zero_for_identical() {
        let mut rng = Pcg::seeded(1);
        let t = Tensor::randn(&[8, 8], &mut rng);
        assert_eq!(rel_l1(&t, &t), 0.0);
    }

    #[test]
    fn psnr_infinite_for_identical_and_finite_otherwise() {
        let mut rng = Pcg::seeded(2);
        let t = Tensor::randn(&[16, 4], &mut rng);
        assert_eq!(psnr(&t, &t), f64::INFINITY);
        let mut u = t.clone();
        u.data_mut()[0] += 0.5;
        let p = psnr(&u, &t);
        assert!(p.is_finite() && p > 0.0);
    }

    #[test]
    fn psnr_decreases_with_noise() {
        let mut rng = Pcg::seeded(3);
        let t = Tensor::randn(&[64, 8], &mut rng);
        let mut small = t.clone();
        let mut big = t.clone();
        for i in 0..t.len() {
            let n = rng.gauss();
            small.data_mut()[i] += 0.01 * n;
            big.data_mut()[i] += 0.5 * n;
        }
        assert!(psnr(&small, &t) > psnr(&big, &t));
    }

    #[test]
    fn cosine_bounds() {
        let a = Tensor::from_vec(&[2], vec![1.0, 0.0]);
        let b = Tensor::from_vec(&[2], vec![0.0, 1.0]);
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-12);
        assert!(cosine(&a, &b).abs() < 1e-12);
        let neg = Tensor::from_vec(&[2], vec![-1.0, 0.0]);
        assert!((cosine(&a, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn block_similarity_high_for_repeated_rows() {
        let row = [0.3f32, -0.2, 0.9, 0.5];
        let mut data = Vec::new();
        for _ in 0..32 {
            data.extend_from_slice(&row);
        }
        let x = Tensor::from_vec(&[32, 4], data);
        assert!(avg_block_similarity(&x, 8) > 0.999);
    }

    #[test]
    fn block_similarity_low_for_random() {
        let mut rng = Pcg::seeded(5);
        let x = Tensor::randn(&[256, 64], &mut rng);
        let s = avg_block_similarity(&x, 64);
        assert!(s < 0.3, "random sim {s}");
    }
}
