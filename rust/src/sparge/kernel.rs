//! The SpargeAttn sparse FlashAttention kernel (Alg. 1) — L3 engine with
//! *real* block skipping, in both f32 and SageAttention-INT8 variants.
//!
//! Stage 1: blocks with `M_g[i,j] = 0` skip both `Q_iK_jᵀ` and `P̃_ijV_j`.
//! Stage 2: inside visited blocks, a row group (warp, `c_w` groups per
//! q-tile) skips its `P̃V` product when `max(m_local − m_ij) < λ`.

use crate::attention::flash::{score_block, FlashTile};
use crate::attention::types::{AttnConfig, BlockMask, SkipStats};
use crate::tensor::quant::{self, QuantBlock};
use crate::tensor::Tensor;

use super::predict::{predict, PredictParams};

/// Full SpargeAttn hyper-parameter set for one attention layer/head.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpargeParams {
    /// TopCdf coverage τ ∈ (0,1).
    pub tau: f32,
    /// Self-similarity threshold θ ∈ (−1,1).
    pub theta: f32,
    /// Stage-2 online-softmax threshold λ < 0 (`None` disables stage 2).
    pub lambda: Option<f32>,
    /// Use the SageAttention INT8 quantized QKᵀ path.
    pub quant: bool,
}

impl Default for SpargeParams {
    fn default() -> Self {
        SpargeParams { tau: 0.9, theta: 0.5, lambda: Some(-5.0), quant: false }
    }
}

impl SpargeParams {
    pub fn predict_params(&self) -> PredictParams {
        PredictParams { tau: self.tau, theta: self.theta }
    }
}

/// Result of a sparse attention call.
#[derive(Clone, Debug)]
pub struct SpargeOutput {
    pub out: Tensor,
    pub stats: SkipStats,
    /// The stage-1 mask that was used (for analysis benches).
    pub mask: BlockMask,
}

/// Run SpargeAttn end to end: predict `M_g`, then sparse flash attention.
pub fn sparge_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> SpargeOutput {
    let pred = predict(q, k, cfg, &params.predict_params());
    let (out, stats) = sparse_flash(q, k, v, &pred.mask, cfg, params);
    SpargeOutput { out, stats, mask: pred.mask }
}

/// Sparse flash attention with a given block mask (stage 1) and λ filter
/// (stage 2). Exposed separately so benches can drive baseline masks
/// (MInference / FlexPrefill) through the identical kernel.
pub fn sparse_flash(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> (Tensor, SkipStats) {
    if params.quant {
        sparse_flash_quant(q, k, v, mask, cfg, params)
    } else {
        sparse_flash_f32(q, k, v, mask, cfg, params)
    }
}

fn sparse_flash_f32(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> (Tensor, SkipStats) {
    assert_eq!(q.dim(1), k.dim(1));
    assert_eq!(k.dim(0), v.dim(0));
    let n = q.dim(0);
    let nk = k.dim(0);
    let dv = v.dim(1);
    let scale = cfg.scale_for(q.dim(1));
    assert_eq!(mask.rows, cfg.n_qblocks(n), "mask rows");
    assert_eq!(mask.cols, cfg.n_kblocks(nk), "mask cols");

    let mut out = Tensor::zeros(&[n, dv]);
    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    let mut sbuf = vec![0f32; cfg.bq * cfg.bk];

    for bi in 0..mask.rows {
        let q0 = bi * cfg.bq;
        let q1 = (q0 + cfg.bq).min(n);
        let mut tile = FlashTile::new(q1 - q0, dv, cfg.bk);
        for bj in 0..mask.cols {
            let k0 = bj * cfg.bk;
            let k1 = (k0 + cfg.bk).min(nk);
            if cfg.causal && k0 > q1 - 1 {
                break; // outside full-attention domain: not counted
            }
            stats.qk_total += 1;
            stats.pv_total += 1;
            if !mask.get(bi, bj) {
                stats.qk_skipped += 1;
                stats.pv_skipped += 1;
                continue;
            }
            score_block(q, k, q0, q1, k0, k1, scale, cfg.causal, &mut sbuf);
            tile.ingest(
                &sbuf[..(q1 - q0) * (k1 - k0)],
                k1 - k0,
                &v.data()[k0 * dv..k1 * dv],
                params.lambda,
                cfg.cw,
                &mut stats,
            );
        }
        out.data_mut()[q0 * dv..q1 * dv].copy_from_slice(&tile.finalize());
    }
    (out, stats)
}

/// SageAttention-integrated path: per-block INT8 Q/K with K smoothing; the
/// QKᵀ product runs in int8→i32 and is dequantized with δ_Q·δ_K (Alg. 1
/// lines 3 & 12). P̃ and V stay f32 (SageAttention keeps PV in higher
/// precision).
fn sparse_flash_quant(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> (Tensor, SkipStats) {
    assert_eq!(q.dim(1), k.dim(1));
    assert_eq!(k.dim(0), v.dim(0));
    let n = q.dim(0);
    let _nk = k.dim(0);
    let d = q.dim(1);
    let dv = v.dim(1);
    let scale = cfg.scale_for(d);

    // K smoothing: subtracting the per-channel mean shifts every row of
    // S_ij by the same amount (Q_i·k̄ᵀ), which row-softmax cancels — but
    // only when *all* key blocks see the same shift. That holds because the
    // smoothing mean is global over K.
    let kmean = quant::channel_mean(k);
    let ksm = quant::smooth(k, &kmean);
    let qb: Vec<QuantBlock> = quant::quantize_blocks(q, cfg.bq);
    let kb: Vec<QuantBlock> = quant::quantize_blocks(&ksm, cfg.bk);

    let mut out = Tensor::zeros(&[n, dv]);
    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    let mut sbuf = vec![0f32; cfg.bq * cfg.bk];

    for (bi, qblk) in qb.iter().enumerate() {
        let q0 = bi * cfg.bq;
        let q1 = q0 + qblk.rows;
        let mut tile = FlashTile::new(qblk.rows, dv, cfg.bk);
        for (bj, kblk) in kb.iter().enumerate() {
            let k0 = bj * cfg.bk;
            let k1 = k0 + kblk.rows;
            if cfg.causal && k0 > q1 - 1 {
                break;
            }
            stats.qk_total += 1;
            stats.pv_total += 1;
            if !mask.get(bi, bj) {
                stats.qk_skipped += 1;
                stats.pv_skipped += 1;
                continue;
            }
            let sb = &mut sbuf[..qblk.rows * kblk.rows];
            quant::qk_dequant(qblk, kblk, scale, sb);
            if cfg.causal {
                for i in 0..qblk.rows {
                    let gi = q0 + i;
                    for j in 0..kblk.rows {
                        if k0 + j > gi {
                            sb[i * kblk.rows + j] = f32::NEG_INFINITY;
                        }
                    }
                }
            }
            tile.ingest(sb, kblk.rows, &v.data()[k0 * dv..k1 * dv], params.lambda, cfg.cw, &mut stats);
        }
        out.data_mut()[q0 * dv..q1 * dv].copy_from_slice(&tile.finalize());
    }
    (out, stats)
}

/// Multi-head sparge attention with per-head stats, parallel over heads.
pub fn sparge_attention_heads(
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
    cfg: &AttnConfig,
    params: &SpargeParams,
    threads: usize,
) -> (Vec<Tensor>, SkipStats) {
    assert_eq!(q.len(), k.len());
    assert_eq!(k.len(), v.len());
    let results = crate::util::threadpool::parallel_map(q.len(), threads, |h| {
        sparge_attention(&q[h], &k[h], &v[h], cfg, params)
    });
    let mut stats = SkipStats::default();
    let mut outs = Vec::with_capacity(results.len());
    for r in results {
        stats.merge(&r.stats);
        outs.push(r.out);
    }
    (outs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::attention_naive;
    use crate::attention::flash::attention_flash;
    use crate::util::prop::{assert_allclose, rel_l1, Cases};
    use crate::util::rng::Pcg;

    fn cfg(bq: usize, bk: usize, causal: bool, cw: usize) -> AttnConfig {
        AttnConfig { bq, bk, causal, scale: None, cw }
    }

    fn dense_params() -> SpargeParams {
        SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: false }
    }

    #[test]
    fn full_mask_no_lambda_equals_dense_flash() {
        Cases::standard(701).check(|rng| {
            let n = rng.range(4, 70);
            let d = 8;
            let c = cfg(rng.range(2, 17), rng.range(2, 17), rng.chance(0.5), rng.range(1, 4));
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
            let (sparse, stats) = sparse_flash(&q, &k, &v, &mask, &c, &dense_params());
            let dense = attention_flash(&q, &k, &v, &c);
            if stats.sparsity() != 0.0 {
                return Err("full mask must have zero sparsity".into());
            }
            assert_allclose(sparse.data(), dense.data(), 1e-4, 1e-3, "full-mask")
        });
    }

    /// The core semantic invariant: skipping a masked block must equal
    /// computing it with S = −∞ (i.e. masking in the oracle).
    #[test]
    fn skipping_equals_masking_property() {
        Cases::standard(702).check(|rng| {
            let n = rng.range(8, 64);
            let d = 8;
            let c = cfg(8, 8, false, 2);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            // random mask, at least one block per row
            let (tm, tn) = (c.n_qblocks(n), c.n_kblocks(n));
            let mut mask = BlockMask::new_all(tm, tn, false);
            for i in 0..tm {
                mask.set(i, rng.range(0, tn), true);
                for j in 0..tn {
                    if rng.chance(0.5) {
                        mask.set(i, j, true);
                    }
                }
            }
            let (sparse, _) = sparse_flash(&q, &k, &v, &mask, &c, &dense_params());

            // oracle: dense with masked blocks set to -inf pre-softmax
            let scale = c.scale_for(d);
            let mut s = crate::tensor::matmul::matmul_nt(&q, &k);
            s.scale(scale);
            for i in 0..n {
                for j in 0..n {
                    if !mask.get(i / c.bq, j / c.bk) {
                        *s.at2_mut(i, j) = f32::NEG_INFINITY;
                    }
                }
            }
            let p = crate::tensor::ops::softmax_rows(&s);
            let oracle = crate::tensor::matmul::matmul_nn(&p, &v);
            assert_allclose(sparse.data(), oracle.data(), 1e-4, 1e-3, "skip-vs-mask")
        });
    }

    #[test]
    fn lambda_very_negative_is_lossless() {
        Cases::standard(703).check(|rng| {
            let n = rng.range(8, 64);
            let d = 8;
            let c = cfg(8, 8, false, 2);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
            let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: Some(-1e30), quant: false };
            let (sparse, _) = sparse_flash(&q, &k, &v, &mask, &c, &params);
            let dense = attention_flash(&q, &k, &v, &c);
            assert_allclose(sparse.data(), dense.data(), 1e-4, 1e-3, "lambda-lossless")
        });
    }

    #[test]
    fn lambda_moderate_bounds_l1_error() {
        let mut rng = Pcg::seeded(31);
        let n = 256;
        let d = 16;
        let c = cfg(32, 32, false, 4);
        // spiky scores: a few huge keys dominate => many skippable blocks
        let q = Tensor::randn(&[n, d], &mut rng);
        let mut k = Tensor::randn(&[n, d], &mut rng);
        for r in 0..8 {
            for x in k.row_mut(r * 32) {
                *x *= 12.0;
            }
        }
        let v = Tensor::randn(&[n, d], &mut rng);
        let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: Some(-8.0), quant: false };
        let (sparse, stats) = sparse_flash(&q, &k, &v, &mask, &c, &params);
        let dense = attention_flash(&q, &k, &v, &c);
        let err = rel_l1(sparse.data(), dense.data());
        assert!(err < 0.02, "lambda path rel-L1 {err}");
        assert!(stats.pv_skipped_groups > 0, "lambda never fired");
    }

    #[test]
    fn quant_path_close_to_f32() {
        Cases::standard(704).check(|rng| {
            let n = rng.range(16, 80);
            let d = 16;
            let c = cfg(16, 16, rng.chance(0.5), 2);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
            let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: true };
            let (qout, _) = sparse_flash(&q, &k, &v, &mask, &c, &params);
            let dense = attention_naive(&q, &k, &v, &c);
            let err = rel_l1(qout.data(), dense.data());
            if err > 0.03 {
                return Err(format!("int8 rel-L1 {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn quant_k_smoothing_handles_offset_keys() {
        // A large common K offset would wreck naive int8; smoothing fixes it.
        let mut rng = Pcg::seeded(33);
        let (n, d) = (64, 16);
        let q = Tensor::randn(&[n, d], &mut rng);
        let mut k = Tensor::randn(&[n, d], &mut rng);
        for i in 0..n {
            for x in k.row_mut(i) {
                *x += 12.0;
            }
        }
        let v = Tensor::randn(&[n, d], &mut rng);
        let c = cfg(16, 16, false, 2);
        let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: true };
        let (qout, _) = sparse_flash(&q, &k, &v, &mask, &c, &params);
        let dense = attention_naive(&q, &k, &v, &c);
        let err = rel_l1(qout.data(), dense.data());
        assert!(err < 0.03, "smoothed int8 rel-L1 {err}");
    }

    #[test]
    fn end_to_end_sparge_accuracy_on_local_pattern() {
        // Strong local attention: sparge should reach decent sparsity with
        // tiny L1 error.
        let mut rng = Pcg::seeded(34);
        let n = 512;
        let d = 32;
        let c = cfg(64, 32, false, 4);
        // locality: token t's q/k dominated by block direction
        let nb = 8;
        let mut dirs = Vec::new();
        for _ in 0..nb {
            let mut u = rng.gauss_vec(d);
            let nm = crate::tensor::ops::norm(&u);
            for x in &mut u {
                *x /= nm;
            }
            dirs.push(u);
        }
        let mut q = Tensor::zeros(&[n, d]);
        let mut k = Tensor::zeros(&[n, d]);
        for t in 0..n {
            let b = (t * nb) / n;
            for (i, x) in q.row_mut(t).iter_mut().enumerate() {
                *x = dirs[b][i] * 6.0 + rng.gauss() * 0.3;
            }
            for (i, x) in k.row_mut(t).iter_mut().enumerate() {
                *x = dirs[b][i] * 6.0 + rng.gauss() * 0.3;
            }
        }
        let v = Tensor::randn(&[n, d], &mut rng);
        let params = SpargeParams { tau: 0.95, theta: 0.3, lambda: Some(-6.0), quant: false };
        let res = sparge_attention(&q, &k, &v, &c, &params);
        let dense = attention_flash(&q, &k, &v, &c);
        let err = rel_l1(res.out.data(), dense.data());
        assert!(err < 0.05, "rel-L1 {err}");
        assert!(res.stats.sparsity() > 0.3, "sparsity {}", res.stats.sparsity());
    }

    #[test]
    fn heads_parallel_matches_serial() {
        let mut rng = Pcg::seeded(35);
        let mk = |rng: &mut Pcg| Tensor::randn(&[64, 8], rng);
        let q: Vec<Tensor> = (0..4).map(|_| mk(&mut rng)).collect();
        let k: Vec<Tensor> = (0..4).map(|_| mk(&mut rng)).collect();
        let v: Vec<Tensor> = (0..4).map(|_| mk(&mut rng)).collect();
        let c = cfg(16, 16, false, 2);
        let p = SpargeParams::default();
        let (par, stats) = sparge_attention_heads(&q, &k, &v, &c, &p, 4);
        for h in 0..4 {
            let serial = sparge_attention(&q[h], &k[h], &v[h], &c, &p);
            assert_eq!(par[h], serial.out, "head {h}");
        }
        assert_eq!(stats.qk_total, 4 * 16);
    }

    #[test]
    fn causal_sparge_matches_causal_dense_at_tau1() {
        let mut rng = Pcg::seeded(36);
        let (n, d) = (96, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let c = cfg(16, 16, true, 2);
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: false };
        let res = sparge_attention(&q, &k, &v, &c, &params);
        let dense = attention_naive(&q, &k, &v, &c);
        assert_allclose(res.out.data(), dense.data(), 1e-4, 1e-3, "causal-tau1").unwrap();
        assert_eq!(res.stats.sparsity(), 0.0);
    }
}
