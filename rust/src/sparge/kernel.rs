//! The SpargeAttn sparse FlashAttention kernel (Alg. 1) — L3 engine with
//! *real* block skipping, in both f32 and SageAttention-INT8 variants.
//!
//! Both variants are compositions over the unified attention API
//! ([`crate::attention::AttnEngine`]): the stage-1/stage-2 filter is a
//! `MaskFilter` (`M_g` lookup + λ threshold), and the score path is either
//! the shared `F32Kernel` or the [`QuantScoreKernel`] defined here
//! (SageAttention INT8 dequant scoring, §3.5).
//!
//! Stage 1: blocks with `M_g[i,j] = 0` skip both `Q_iK_jᵀ` and `P̃_ijV_j`.
//! Stage 2: inside visited blocks, a row group (warp, `c_w` groups per
//! q-tile) skips its `P̃V` product when `max(m_local − m_ij) < λ`.
//!
//! The free functions here are **deprecated shims** over the engine
//! builder; see the migration table in [`crate::attention`].

use crate::attention::engine::{AttnEngine, Execution, Precision, SparsityPolicy};
use crate::attention::pipeline::{ScoreKernel, ScoreScratch};
use crate::attention::types::{AttnConfig, BlockMask, SkipStats};
use crate::tensor::microkernel::Backend;
use crate::tensor::quant::{self, QuantBlock};
use crate::tensor::Tensor;

use super::predict::PredictParams;

/// Full SpargeAttn hyper-parameter set for one attention layer/head.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpargeParams {
    /// TopCdf coverage τ ∈ (0,1).
    pub tau: f32,
    /// Self-similarity threshold θ ∈ (−1,1).
    pub theta: f32,
    /// Stage-2 online-softmax threshold λ < 0 (`None` disables stage 2).
    pub lambda: Option<f32>,
    /// Use the SageAttention INT8 quantized QKᵀ path.
    pub quant: bool,
}

impl Default for SpargeParams {
    fn default() -> Self {
        SpargeParams { tau: 0.9, theta: 0.5, lambda: Some(-5.0), quant: false }
    }
}

impl SpargeParams {
    pub fn predict_params(&self) -> PredictParams {
        PredictParams { tau: self.tau, theta: self.theta }
    }

    /// Engine precision implied by `quant`.
    pub fn precision(&self) -> Precision {
        if self.quant {
            Precision::Int8
        } else {
            Precision::F32
        }
    }
}

/// Result of a sparse attention call.
#[derive(Clone, Debug)]
pub struct SpargeOutput {
    pub out: Tensor,
    pub stats: SkipStats,
    /// The stage-1 mask that was used (for analysis benches).
    pub mask: BlockMask,
}

/// SageAttention-integrated score path: per-block INT8 Q/K with K
/// smoothing; the QKᵀ product runs in int8→i32 and is dequantized with
/// δ_Q·δ_K (Alg. 1 lines 3 & 12). P̃ and V stay f32 (SageAttention keeps
/// PV in higher precision). Causal masking of the dequantized block is
/// applied here, inside the kernel, like every other `ScoreKernel`.
///
/// Like the f32 kernel, scoring is pure per (q-block, k-block) pair —
/// blocks are quantized independently and the smoothing shift is global
/// — so the kernel serves both pipeline drivers unchanged: `run_tiled`'s
/// row order and `run_tiled_splitkv`'s span partition read the same
/// per-block payloads.
pub struct QuantScoreKernel {
    qb: Vec<QuantBlock>,
    kb: Vec<QuantBlock>,
    scale: f32,
    causal: bool,
    bq: usize,
    bk: usize,
    row_offset: usize,
    mk: Backend,
}

impl QuantScoreKernel {
    /// Pre-quantize Q and (smoothed) K. Under causal masking only the key
    /// blocks inside the causal domain — those whose first row is ≤ the
    /// last query row's absolute position (`cfg.row_offset` + local row) —
    /// are ever scored, so quantization stops at that bound instead of
    /// wastefully covering the unreachable upper triangle.
    pub fn new(q: &Tensor, k: &Tensor, cfg: &AttnConfig) -> QuantScoreKernel {
        assert_eq!(q.dim(1), k.dim(1), "q/k head dim");
        let n = q.dim(0);
        let nk = k.dim(0);

        // K smoothing: subtracting the per-channel mean shifts every row of
        // S_ij by the same amount (Q_i·k̄ᵀ), which row-softmax cancels — but
        // only when *all* key blocks see the same shift. That holds because
        // the smoothing mean is global over K (including any rows past the
        // causal bound).
        let kmean = quant::channel_mean(k);
        let ksm = quant::smooth(k, &kmean);

        // Causal domain: the deepest q-tile ends at absolute position
        // row_offset + n, reaching key blocks bj with bj·bk < row_offset + n.
        let k_reach =
            if cfg.causal { nk.min((cfg.row_offset + n).div_ceil(cfg.bk) * cfg.bk) } else { nk };
        let qb = quant::quantize_blocks(q, cfg.bq);
        let kb = if k_reach == nk {
            quant::quantize_blocks(&ksm, cfg.bk)
        } else {
            quant::quantize_blocks(&ksm.rows(0, k_reach), cfg.bk)
        };
        let scale = cfg.scale_for(q.dim(1));
        QuantScoreKernel {
            qb,
            kb,
            scale,
            causal: cfg.causal,
            bq: cfg.bq,
            bk: cfg.bk,
            row_offset: cfg.row_offset,
            mk: Backend::select(),
        }
    }

    /// Pin the kernel to an explicit microkernel backend (the engine
    /// builder's `.microkernel(...)` plumbs through here). The INT8 dot
    /// is exact on every backend, so this never changes results.
    pub fn with_microkernel(mut self, mk: Backend) -> QuantScoreKernel {
        self.mk = mk;
        self
    }
}

impl ScoreKernel for QuantScoreKernel {
    fn score_block(
        &self,
        q0: usize,
        q1: usize,
        k0: usize,
        k1: usize,
        out: &mut [f32],
        scratch: &mut ScoreScratch<'_>,
    ) {
        let qblk = &self.qb[q0 / self.bq];
        let kblk = &self.kb[k0 / self.bk];
        debug_assert_eq!(qblk.rows, q1 - q0);
        debug_assert_eq!(kblk.rows, k1 - k0);
        let q0_abs = self.row_offset + q0;
        quant_score_block(
            self.mk,
            qblk,
            kblk,
            q0_abs,
            k0,
            self.scale,
            self.causal,
            out,
            scratch.acc_i32,
        );
    }

    fn microkernel(&self) -> Backend {
        self.mk
    }
}

/// Dequantized, optionally causal-masked score block for one (Q, K) block
/// pair — shared by [`QuantScoreKernel`] and the session's cache kernel
/// (which borrows cached K blocks instead of owning them). `q0` is the
/// **absolute position** of the block's first query row (callers add
/// their `row_offset`); `k0` is the absolute first key row. `acc` is the
/// running thread's i32 staging buffer (see
/// [`crate::attention::pipeline::ScoreScratch`]) — nothing here
/// allocates.
#[allow(clippy::too_many_arguments)]
pub(crate) fn quant_score_block(
    mk: Backend,
    qblk: &QuantBlock,
    kblk: &QuantBlock,
    q0: usize,
    k0: usize,
    scale: f32,
    causal: bool,
    out: &mut [f32],
    acc: &mut Vec<i32>,
) {
    quant::qk_dequant_scratch_with(mk, qblk, kblk, scale, out, acc);
    if causal {
        for i in 0..qblk.rows {
            let gi = q0 + i;
            for j in 0..kblk.rows {
                if k0 + j > gi {
                    out[i * kblk.rows + j] = f32::NEG_INFINITY;
                }
            }
        }
    }
}

/// Run SpargeAttn end to end: predict `M_g`, then sparse flash attention.
#[deprecated(note = "build an AttnEngine::sparge(cfg, params) once and call .attention(q, k, v)")]
pub fn sparge_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> SpargeOutput {
    let r = AttnEngine::sparge(*cfg, params).attention(q, k, v);
    SpargeOutput { out: r.out, stats: r.stats, mask: r.mask.expect("predicted policy yields a mask") }
}

/// [`sparge_attention`] with query-block rows fanned across `threads`
/// workers inside the kernel (for single-head long-sequence workloads).
#[deprecated(note = "use AttnEngine::builder().sparge(params) + Execution::Threads(n) or ::Pool(n)")]
pub fn sparge_attention_threads(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    params: &SpargeParams,
    threads: usize,
) -> SpargeOutput {
    let engine =
        AttnEngine::builder().config(*cfg).sparge(params).execution(Execution::Threads(threads)).build();
    let r = engine.attention(q, k, v);
    SpargeOutput { out: r.out, stats: r.stats, mask: r.mask.expect("predicted policy yields a mask") }
}

/// Sparse flash attention with a given block mask (stage 1) and λ filter
/// (stage 2).
#[deprecated(note = "use AttnEngine::builder().policy(SparsityPolicy::External { mask, lambda })")]
pub fn sparse_flash(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> (Tensor, SkipStats) {
    let engine = AttnEngine::builder()
        .config(*cfg)
        .precision(params.precision())
        .policy(SparsityPolicy::External { mask: mask.clone(), lambda: params.lambda })
        .build();
    let r = engine.attention(q, k, v);
    (r.out, r.stats)
}

/// [`sparse_flash`] parallel over query-block rows. Output and stats are
/// bitwise identical for every thread count.
#[deprecated(note = "use AttnEngine::builder().policy(SparsityPolicy::External) + Execution::Threads(n)")]
pub fn sparse_flash_threads(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    params: &SpargeParams,
    threads: usize,
) -> (Tensor, SkipStats) {
    let engine = AttnEngine::builder()
        .config(*cfg)
        .precision(params.precision())
        .policy(SparsityPolicy::External { mask: mask.clone(), lambda: params.lambda })
        .execution(Execution::Threads(threads))
        .build();
    let r = engine.attention(q, k, v);
    (r.out, r.stats)
}

/// Multi-head sparge attention with per-head stats, parallel over heads.
/// One shared engine serves every head worker (it is `Sync`); rows within
/// a head stay serial — head-level fan-out already saturates the
/// `threads` budget.
pub fn sparge_attention_heads(
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
    cfg: &AttnConfig,
    params: &SpargeParams,
    threads: usize,
) -> (Vec<Tensor>, SkipStats) {
    assert_eq!(q.len(), k.len());
    assert_eq!(k.len(), v.len());
    let engine = AttnEngine::sparge(*cfg, params);
    let results =
        crate::util::threadpool::parallel_map(q.len(), threads, |h| engine.attention(&q[h], &k[h], &v[h]));
    let mut stats = SkipStats::default();
    let mut outs = Vec::with_capacity(results.len());
    for r in results {
        stats.merge(&r.stats);
        outs.push(r.out);
    }
    (outs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::attention_naive;
    use crate::attention::engine::AttnOutput;
    use crate::sparge::predict::predict;
    use crate::util::prop::{assert_allclose, rel_l1, Cases};
    use crate::util::rng::Pcg;

    fn cfg(bq: usize, bk: usize, causal: bool, cw: usize) -> AttnConfig {
        AttnConfig { bq, bk, causal, scale: None, cw, row_offset: 0 }
    }

    fn dense_params() -> SpargeParams {
        SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: false }
    }

    /// External-mask engine one-shot (the old `sparse_flash`).
    fn sf(
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        mask: &BlockMask,
        c: &AttnConfig,
        params: &SpargeParams,
    ) -> (Tensor, SkipStats) {
        let engine = AttnEngine::builder()
            .config(*c)
            .precision(params.precision())
            .policy(SparsityPolicy::External { mask: mask.clone(), lambda: params.lambda })
            .build();
        let r = engine.attention(q, k, v);
        (r.out, r.stats)
    }

    /// Predicted-policy engine one-shot (the old `sparge_attention`).
    fn sa(q: &Tensor, k: &Tensor, v: &Tensor, c: &AttnConfig, params: &SpargeParams) -> AttnOutput {
        AttnEngine::sparge(*c, params).attention(q, k, v)
    }

    fn dense_flash(q: &Tensor, k: &Tensor, v: &Tensor, c: &AttnConfig) -> Tensor {
        AttnEngine::dense(*c).attention(q, k, v).out
    }

    #[test]
    fn full_mask_no_lambda_equals_dense_flash() {
        Cases::standard(701).check(|rng| {
            let n = rng.range(4, 70);
            let d = 8;
            let c = cfg(rng.range(2, 17), rng.range(2, 17), rng.chance(0.5), rng.range(1, 4));
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
            let (sparse, stats) = sf(&q, &k, &v, &mask, &c, &dense_params());
            let dense = dense_flash(&q, &k, &v, &c);
            if stats.sparsity() != 0.0 {
                return Err("full mask must have zero sparsity".into());
            }
            assert_allclose(sparse.data(), dense.data(), 1e-4, 1e-3, "full-mask")
        });
    }

    /// The core semantic invariant: skipping a masked block must equal
    /// computing it with S = −∞ (i.e. masking in the oracle).
    #[test]
    fn skipping_equals_masking_property() {
        Cases::standard(702).check(|rng| {
            let n = rng.range(8, 64);
            let d = 8;
            let c = cfg(8, 8, false, 2);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            // random mask, at least one block per row
            let (tm, tn) = (c.n_qblocks(n), c.n_kblocks(n));
            let mut mask = BlockMask::new_all(tm, tn, false);
            for i in 0..tm {
                mask.set(i, rng.range(0, tn), true);
                for j in 0..tn {
                    if rng.chance(0.5) {
                        mask.set(i, j, true);
                    }
                }
            }
            let (sparse, _) = sf(&q, &k, &v, &mask, &c, &dense_params());

            // oracle: dense with masked blocks set to -inf pre-softmax
            let scale = c.scale_for(d);
            let mut s = crate::tensor::matmul::matmul_nt(&q, &k);
            s.scale(scale);
            for i in 0..n {
                for j in 0..n {
                    if !mask.get(i / c.bq, j / c.bk) {
                        *s.at2_mut(i, j) = f32::NEG_INFINITY;
                    }
                }
            }
            let p = crate::tensor::ops::softmax_rows(&s);
            let oracle = crate::tensor::matmul::matmul_nn(&p, &v);
            assert_allclose(sparse.data(), oracle.data(), 1e-4, 1e-3, "skip-vs-mask")
        });
    }

    #[test]
    fn lambda_very_negative_is_lossless() {
        Cases::standard(703).check(|rng| {
            let n = rng.range(8, 64);
            let d = 8;
            let c = cfg(8, 8, false, 2);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
            let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: Some(-1e30), quant: false };
            let (sparse, _) = sf(&q, &k, &v, &mask, &c, &params);
            let dense = dense_flash(&q, &k, &v, &c);
            assert_allclose(sparse.data(), dense.data(), 1e-4, 1e-3, "lambda-lossless")
        });
    }

    #[test]
    fn lambda_moderate_bounds_l1_error() {
        let mut rng = Pcg::seeded(31);
        let n = 256;
        let d = 16;
        let c = cfg(32, 32, false, 4);
        // spiky scores: a few huge keys dominate => many skippable blocks
        let q = Tensor::randn(&[n, d], &mut rng);
        let mut k = Tensor::randn(&[n, d], &mut rng);
        for r in 0..8 {
            for x in k.row_mut(r * 32) {
                *x *= 12.0;
            }
        }
        let v = Tensor::randn(&[n, d], &mut rng);
        let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: Some(-8.0), quant: false };
        let (sparse, stats) = sf(&q, &k, &v, &mask, &c, &params);
        let dense = dense_flash(&q, &k, &v, &c);
        let err = rel_l1(sparse.data(), dense.data());
        assert!(err < 0.02, "lambda path rel-L1 {err}");
        assert!(stats.pv_skipped_frac > 0.0, "lambda never fired");
    }

    #[test]
    fn quant_path_close_to_f32() {
        Cases::standard(704).check(|rng| {
            let n = rng.range(16, 80);
            let d = 16;
            let c = cfg(16, 16, rng.chance(0.5), 2);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
            let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: true };
            let (qout, _) = sf(&q, &k, &v, &mask, &c, &params);
            let dense = attention_naive(&q, &k, &v, &c);
            let err = rel_l1(qout.data(), dense.data());
            if err > 0.03 {
                return Err(format!("int8 rel-L1 {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn quant_k_smoothing_handles_offset_keys() {
        // A large common K offset would wreck naive int8; smoothing fixes it.
        let mut rng = Pcg::seeded(33);
        let (n, d) = (64, 16);
        let q = Tensor::randn(&[n, d], &mut rng);
        let mut k = Tensor::randn(&[n, d], &mut rng);
        for i in 0..n {
            for x in k.row_mut(i) {
                *x += 12.0;
            }
        }
        let v = Tensor::randn(&[n, d], &mut rng);
        let c = cfg(16, 16, false, 2);
        let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: true };
        let (qout, _) = sf(&q, &k, &v, &mask, &c, &params);
        let dense = attention_naive(&q, &k, &v, &c);
        let err = rel_l1(qout.data(), dense.data());
        assert!(err < 0.03, "smoothed int8 rel-L1 {err}");
    }

    /// Regression: the quant and f32 paths must report *identical* block
    /// counters on the same mask — the causal-domain bound is shared by the
    /// unified driver, never re-derived per score path.
    #[test]
    fn quant_and_f32_stats_are_byte_identical() {
        Cases::standard(705).check(|rng| {
            let n = rng.range(16, 96);
            let d = 16;
            let c = cfg(rng.range(4, 20), rng.range(4, 20), rng.chance(0.5), 2);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let (tm, tn) = (c.n_qblocks(n), c.n_kblocks(n));
            let mut mask = BlockMask::new_all(tm, tn, false);
            for i in 0..tm {
                mask.set(i, rng.range(0, tn), true);
                for j in 0..tn {
                    if rng.chance(0.6) {
                        mask.set(i, j, true);
                    }
                }
            }
            let (_, st_f) = sf(&q, &k, &v, &mask, &c, &dense_params());
            let (_, st_q) = sf(&q, &k, &v, &mask, &c, &SpargeParams { quant: true, ..dense_params() });
            if st_f != st_q {
                return Err(format!("stats diverge: f32 {st_f:?} vs quant {st_q:?}"));
            }
            if st_f.qk_total != st_q.qk_total {
                return Err("qk_total asymmetry".into());
            }
            Ok(())
        });
    }

    /// The causal-domain bound on K quantization must not change outputs:
    /// causal quant attention only ever reads the blocks that remain.
    #[test]
    fn causal_quant_matches_noncausal_prefix_quantization() {
        let mut rng = Pcg::seeded(37);
        let (n, d) = (96, 16);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let c = cfg(16, 16, true, 2);
        let mask = BlockMask::new_all(c.n_qblocks(n), c.n_kblocks(n), true);
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: true };
        let (qout, _) = sf(&q, &k, &v, &mask, &c, &params);
        let dense = attention_naive(&q, &k, &v, &c);
        let err = rel_l1(qout.data(), dense.data());
        assert!(err < 0.03, "causal int8 rel-L1 {err}");
    }

    #[test]
    fn end_to_end_sparge_accuracy_on_local_pattern() {
        // Strong local attention: sparge should reach decent sparsity with
        // tiny L1 error.
        let mut rng = Pcg::seeded(34);
        let n = 512;
        let d = 32;
        let c = cfg(64, 32, false, 4);
        // locality: token t's q/k dominated by block direction
        let nb = 8;
        let mut dirs = Vec::new();
        for _ in 0..nb {
            let mut u = rng.gauss_vec(d);
            let nm = crate::tensor::ops::norm(&u);
            for x in &mut u {
                *x /= nm;
            }
            dirs.push(u);
        }
        let mut q = Tensor::zeros(&[n, d]);
        let mut k = Tensor::zeros(&[n, d]);
        for t in 0..n {
            let b = (t * nb) / n;
            for (i, x) in q.row_mut(t).iter_mut().enumerate() {
                *x = dirs[b][i] * 6.0 + rng.gauss() * 0.3;
            }
            for (i, x) in k.row_mut(t).iter_mut().enumerate() {
                *x = dirs[b][i] * 6.0 + rng.gauss() * 0.3;
            }
        }
        let v = Tensor::randn(&[n, d], &mut rng);
        let params = SpargeParams { tau: 0.95, theta: 0.3, lambda: Some(-6.0), quant: false };
        let res = sa(&q, &k, &v, &c, &params);
        let dense = dense_flash(&q, &k, &v, &c);
        let err = rel_l1(res.out.data(), dense.data());
        assert!(err < 0.05, "rel-L1 {err}");
        assert!(res.stats.sparsity() > 0.3, "sparsity {}", res.stats.sparsity());
    }

    #[test]
    fn heads_parallel_matches_serial() {
        let mut rng = Pcg::seeded(35);
        let mk = |rng: &mut Pcg| Tensor::randn(&[64, 8], rng);
        let q: Vec<Tensor> = (0..4).map(|_| mk(&mut rng)).collect();
        let k: Vec<Tensor> = (0..4).map(|_| mk(&mut rng)).collect();
        let v: Vec<Tensor> = (0..4).map(|_| mk(&mut rng)).collect();
        let c = cfg(16, 16, false, 2);
        let p = SpargeParams::default();
        let (par, stats) = sparge_attention_heads(&q, &k, &v, &c, &p, 4);
        for h in 0..4 {
            let serial = sa(&q[h], &k[h], &v[h], &c, &p);
            assert_eq!(par[h], serial.out, "head {h}");
        }
        assert_eq!(stats.qk_total, 4 * 16);
    }

    #[test]
    fn row_parallel_matches_serial_all_backends() {
        let mut rng = Pcg::seeded(38);
        let (n, d) = (128, 16);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let c = cfg(16, 16, true, 2);
        let mask = predict(&q, &k, &c, &PredictParams { tau: 0.9, theta: 0.3 }).mask;
        for quant in [false, true] {
            let p = SpargeParams { tau: 0.9, theta: 0.3, lambda: Some(-6.0), quant };
            let (o1, s1) = sf(&q, &k, &v, &mask, &c, &p);
            for exec in [Execution::Threads(4), Execution::Pool(4)] {
                let engine = AttnEngine::builder()
                    .config(c)
                    .precision(p.precision())
                    .policy(SparsityPolicy::External { mask: mask.clone(), lambda: p.lambda })
                    .execution(exec)
                    .build();
                let r = engine.attention(&q, &k, &v);
                assert_eq!(o1, r.out, "quant={quant} {exec:?}");
                assert_eq!(s1, r.stats, "quant={quant} {exec:?}");
            }
        }
    }

    #[test]
    fn causal_sparge_matches_causal_dense_at_tau1() {
        let mut rng = Pcg::seeded(36);
        let (n, d) = (96, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let c = cfg(16, 16, true, 2);
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: false };
        let res = sa(&q, &k, &v, &c, &params);
        let dense = attention_naive(&q, &k, &v, &c);
        assert_allclose(res.out.data(), dense.data(), 1e-4, 1e-3, "causal-tau1").unwrap();
        assert_eq!(res.stats.sparsity(), 0.0);
    }

    #[test]
    fn deprecated_shims_match_engine() {
        let mut rng = Pcg::seeded(39);
        let (n, d) = (64, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let c = cfg(16, 16, false, 2);
        let p = SpargeParams::default();
        let engine_res = sa(&q, &k, &v, &c, &p);
        let mask = predict(&q, &k, &c, &p.predict_params()).mask;
        let (mout, mstats) = sf(&q, &k, &v, &mask, &c, &p);
        #[allow(deprecated)]
        {
            let shim = sparge_attention(&q, &k, &v, &c, &p);
            assert_eq!(shim.out, engine_res.out);
            assert_eq!(shim.stats, engine_res.stats);
            assert_eq!(Some(shim.mask), engine_res.mask);
            let shim_t = sparge_attention_threads(&q, &k, &v, &c, &p, 4);
            assert_eq!(shim_t.out, engine_res.out);
            let (so, ss) = sparse_flash(&q, &k, &v, &mask, &c, &p);
            assert_eq!(so, mout);
            assert_eq!(ss, mstats);
            let (so, ss) = sparse_flash_threads(&q, &k, &v, &mask, &c, &p, 3);
            assert_eq!(so, mout);
            assert_eq!(ss, mstats);
        }
    }
}
