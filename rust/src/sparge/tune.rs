//! Per-layer hyper-parameter determination (paper §3.6).
//!
//! Two-stage grid search over a small calibration set (the paper uses five
//! model inputs per layer): first (τ, θ) maximizing sparsity subject to
//! rel-L1 < l1, then λ maximizing sparsity subject to rel-L1 < l2.

use crate::attention::engine::AttnEngine;
use crate::attention::types::AttnConfig;
use crate::tensor::Tensor;

use super::kernel::SpargeParams;
use super::metrics::rel_l1;

/// One calibration sample: a single head's (Q, K, V).
#[derive(Clone, Debug)]
pub struct CalibSample {
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
}

/// Tuning configuration.
#[derive(Clone, Debug)]
pub struct TuneOptions {
    /// Stage-1 error bound l1 (e.g. 0.05).
    pub l1: f64,
    /// Stage-2 error bound l2 (e.g. 0.06), l2 ≥ l1.
    pub l2: f64,
    /// τ grid (descending coverage = ascending sparsity).
    pub tau_grid: Vec<f32>,
    /// θ grid.
    pub theta_grid: Vec<f32>,
    /// λ grid (negative).
    pub lambda_grid: Vec<f32>,
    /// Quantized kernel during tuning.
    pub quant: bool,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            l1: 0.05,
            l2: 0.06,
            tau_grid: vec![0.99, 0.95, 0.9, 0.8, 0.65, 0.5],
            theta_grid: vec![0.0, 0.25, 0.45, 0.65],
            lambda_grid: vec![-12.0, -8.0, -5.0, -3.5],
            quant: false,
        }
    }
}

/// Result of tuning one layer.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub params: SpargeParams,
    /// Mean sparsity over the calibration set at the chosen params.
    pub sparsity: f64,
    /// Worst-case rel-L1 over the calibration set at the chosen params.
    pub l1_error: f64,
    /// Grid points evaluated (for overhead reporting).
    pub evaluated: usize,
}

/// Evaluate one parameter set over the calibration samples: returns
/// (mean sparsity, max rel-L1 vs dense flash).
pub fn evaluate(
    samples: &[CalibSample],
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> (f64, f64) {
    let dense = AttnEngine::dense(*cfg);
    let denses: Vec<Tensor> = samples.iter().map(|s| dense.attention(&s.q, &s.k, &s.v).out).collect();
    evaluate_cached(samples, &denses, cfg, params)
}

/// `evaluate` with precomputed dense references (the tuner computes them
/// once instead of once per grid point — a ~2x wall-clock saving).
fn evaluate_cached(
    samples: &[CalibSample],
    denses: &[Tensor],
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> (f64, f64) {
    let engine = AttnEngine::sparge(*cfg, params);
    let mut sp_sum = 0f64;
    let mut worst = 0f64;
    for (s, dense) in samples.iter().zip(denses) {
        let res = engine.attention(&s.q, &s.k, &s.v);
        sp_sum += res.stats.sparsity();
        worst = worst.max(rel_l1(&res.out, dense));
    }
    (sp_sum / samples.len() as f64, worst)
}

/// Run the two-stage grid search of §3.6.
pub fn tune_layer(samples: &[CalibSample], cfg: &AttnConfig, opts: &TuneOptions) -> TuneResult {
    assert!(!samples.is_empty(), "tuning needs calibration samples");
    assert!(opts.l2 >= opts.l1, "l2 must be >= l1");

    let dense = AttnEngine::dense(*cfg);
    let denses: Vec<Tensor> = samples.iter().map(|s| dense.attention(&s.q, &s.k, &s.v).out).collect();

    // Stage 1: (τ, θ), λ disabled.
    let mut best: Option<(SpargeParams, f64, f64)> = None;
    let mut evaluated = 0usize;
    for &tau in &opts.tau_grid {
        for &theta in &opts.theta_grid {
            let p = SpargeParams { tau, theta, lambda: None, quant: opts.quant };
            let (sp, err) = evaluate_cached(samples, &denses, cfg, &p);
            evaluated += 1;
            if err < opts.l1 && best.as_ref().map(|(_, bs, _)| sp > *bs).unwrap_or(true) {
                best = Some((p, sp, err));
            }
        }
    }
    // Fallback: the densest setting (always meets the bound: τ=1,θ=−1 is
    // exactly dense attention).
    let (mut params, mut sparsity, mut l1_error) = best.unwrap_or_else(|| {
        let p = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: opts.quant };
        let (sp, err) = evaluate_cached(samples, &denses, cfg, &p);
        (p, sp, err)
    });

    // Stage 2: λ grid on top of the stage-1 winner.
    for &lam in &opts.lambda_grid {
        let p = SpargeParams { lambda: Some(lam), ..params };
        let (sp, err) = evaluate_cached(samples, &denses, cfg, &p);
        evaluated += 1;
        if err < opts.l2 && sp > sparsity {
            params = p;
            sparsity = sp;
            l1_error = err;
        }
    }

    TuneResult { params, sparsity, l1_error, evaluated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn local_sample(rng: &mut Pcg, n: usize, d: usize, nb: usize) -> CalibSample {
        // strongly block-local Q/K so real sparsity is attainable
        let mut dirs = Vec::new();
        for _ in 0..nb {
            let mut u = rng.gauss_vec(d);
            let nm = crate::tensor::ops::norm(&u);
            for x in &mut u {
                *x /= nm;
            }
            dirs.push(u);
        }
        let mut q = Tensor::zeros(&[n, d]);
        let mut k = Tensor::zeros(&[n, d]);
        for t in 0..n {
            let b = (t * nb) / n;
            for (i, x) in q.row_mut(t).iter_mut().enumerate() {
                *x = dirs[b][i] * 5.0 + rng.gauss() * 0.25;
            }
            for (i, x) in k.row_mut(t).iter_mut().enumerate() {
                *x = dirs[b][i] * 5.0 + rng.gauss() * 0.25;
            }
        }
        CalibSample { q, k, v: Tensor::randn(&[n, d], rng) }
    }

    #[test]
    fn tuned_params_respect_error_bounds() {
        let mut rng = Pcg::seeded(41);
        let cfg = AttnConfig { bq: 32, bk: 16, causal: false, scale: None, cw: 2, row_offset: 0 };
        let samples: Vec<CalibSample> = (0..3).map(|_| local_sample(&mut rng, 256, 16, 8)).collect();
        let opts = TuneOptions { l1: 0.05, l2: 0.06, ..Default::default() };
        let res = tune_layer(&samples, &cfg, &opts);
        assert!(res.l1_error < opts.l2, "err {} >= l2", res.l1_error);
        assert!(res.sparsity > 0.2, "sparsity {}", res.sparsity);
        assert!(res.evaluated > 10);
    }

    #[test]
    fn tighter_bound_gives_denser_params() {
        let mut rng = Pcg::seeded(42);
        let cfg = AttnConfig { bq: 32, bk: 16, causal: false, scale: None, cw: 2, row_offset: 0 };
        let samples: Vec<CalibSample> = (0..2).map(|_| local_sample(&mut rng, 192, 16, 6)).collect();
        let loose = tune_layer(&samples, &cfg, &TuneOptions { l1: 0.10, l2: 0.12, ..Default::default() });
        let tight = tune_layer(&samples, &cfg, &TuneOptions { l1: 0.005, l2: 0.006, ..Default::default() });
        let (ls, ts) = (loose.sparsity, tight.sparsity);
        assert!(ls >= ts - 1e-9, "loose {ls} < tight {ts}");
        assert!(tight.l1_error < 0.006);
    }

    #[test]
    fn fallback_is_dense_when_nothing_fits() {
        // Impossible bound -> dense fallback with ~zero error.
        let mut rng = Pcg::seeded(43);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: false, scale: None, cw: 2, row_offset: 0 };
        let samples = vec![local_sample(&mut rng, 64, 8, 4)];
        let opts = TuneOptions {
            l1: 1e-12,
            l2: 2e-12,
            tau_grid: vec![0.5],
            theta_grid: vec![0.5],
            lambda_grid: vec![-5.0],
            quant: false,
        };
        let res = tune_layer(&samples, &cfg, &opts);
        assert_eq!(res.params.tau, 1.0);
        assert_eq!(res.params.theta, -1.0);
    }
}
