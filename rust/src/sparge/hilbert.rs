//! Token permutations for visual models (paper §3.7, Table 8/9).
//!
//! Attention is permutation-invariant (modulo the inverse permutation on
//! the output), so flattening T×H×W visual tokens along a locality-
//! preserving curve raises block self-similarity and therefore sparsity.
//! Implements the generalized Hilbert ("gilbert") curve for arbitrary
//! cuboids plus the paper's ablation orders: row-major, column-major,
//! time-major, random.

use crate::tensor::Tensor;
use crate::util::rng::Pcg;

/// Permutation methods ablated in Table 8/9.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Permutation {
    Random,
    RowMajor,
    ColumnMajor,
    TimeMajor,
    HilbertCurve,
}

impl Permutation {
    pub fn name(&self) -> &'static str {
        match self {
            Permutation::Random => "Random",
            Permutation::RowMajor => "Rowmajor",
            Permutation::ColumnMajor => "Columnmajor",
            Permutation::TimeMajor => "Timemajor",
            Permutation::HilbertCurve => "HilbertCurve",
        }
    }

    pub fn all() -> [Permutation; 5] {
        [
            Permutation::Random,
            Permutation::RowMajor,
            Permutation::ColumnMajor,
            Permutation::TimeMajor,
            Permutation::HilbertCurve,
        ]
    }
}

/// Token order for a T×H×W grid: `order[pos] = row-major linear index` of
/// the token that lands at flattened position `pos`.
pub fn token_order(perm: Permutation, t: usize, h: usize, w: usize, seed: u64) -> Vec<usize> {
    let n = t * h * w;
    let lin = |tt: usize, hh: usize, ww: usize| (tt * h + hh) * w + ww;
    match perm {
        Permutation::RowMajor => (0..n).collect(),
        Permutation::ColumnMajor => {
            let mut out = Vec::with_capacity(n);
            for tt in 0..t {
                for ww in 0..w {
                    for hh in 0..h {
                        out.push(lin(tt, hh, ww));
                    }
                }
            }
            out
        }
        Permutation::TimeMajor => {
            let mut out = Vec::with_capacity(n);
            for hh in 0..h {
                for ww in 0..w {
                    for tt in 0..t {
                        out.push(lin(tt, hh, ww));
                    }
                }
            }
            out
        }
        Permutation::Random => {
            let mut rng = Pcg::seeded(seed);
            rng.permutation(n)
        }
        Permutation::HilbertCurve => gilbert3d(t, h, w).iter().map(|&(tt, hh, ww)| lin(tt, hh, ww)).collect(),
    }
}

/// Hilbert-curve traversal of an arbitrary t×h×w cuboid.
///
/// Cells are assigned their Hilbert index inside the smallest enclosing
/// power-of-two cube (computed with Skilling's axes→transpose transform)
/// and visited in index order. On exact power-of-two cubes this *is* the
/// Hilbert curve (every step adjacent); on ragged grids it is the standard
/// restriction of the curve, which preserves the locality the paper's
/// permutation needs (§3.7) while remaining a bijection by construction.
pub fn gilbert3d(t: usize, h: usize, w: usize) -> Vec<(usize, usize, usize)> {
    let maxdim = t.max(h).max(w).max(1);
    let bits = (usize::BITS - (maxdim - 1).leading_zeros()).max(1);
    let mut cells: Vec<(u128, (usize, usize, usize))> = Vec::with_capacity(t * h * w);
    for tt in 0..t {
        for hh in 0..h {
            for ww in 0..w {
                let idx = hilbert_index([tt as u32, hh as u32, ww as u32], bits);
                cells.push((idx, (tt, hh, ww)));
            }
        }
    }
    cells.sort_by_key(|&(idx, _)| idx);
    cells.into_iter().map(|(_, c)| c).collect()
}

/// Hilbert index of a 3-D point with `bits` bits per axis — Skilling's
/// "AxestoTranspose" (J. Skilling, *Programming the Hilbert curve*, 2004)
/// followed by bit interleaving of the transposed coordinates.
pub fn hilbert_index(mut x: [u32; 3], bits: u32) -> u128 {
    let n = 3usize;
    let m = 1u32 << (bits - 1);

    // Inverse undo excess work.
    let mut q = m;
    while q > 1 {
        let p = q - 1;
        for i in 0..n {
            if x[i] & q != 0 {
                x[0] ^= p; // invert low bits of x[0]
            } else {
                let tswap = (x[0] ^ x[i]) & p;
                x[0] ^= tswap;
                x[i] ^= tswap;
            }
        }
        q >>= 1;
    }
    // Gray encode.
    for i in 1..n {
        x[i] ^= x[i - 1];
    }
    let mut tbit = 0u32;
    q = m;
    while q > 1 {
        if x[n - 1] & q != 0 {
            tbit ^= q - 1;
        }
        q >>= 1;
    }
    for xi in x.iter_mut() {
        *xi ^= tbit;
    }

    // Interleave: bit b of axis i lands at position (bits-1-b)*3 + (n-1-i)
    // reading x[0] as the most significant axis.
    let mut out: u128 = 0;
    for b in (0..bits).rev() {
        for xi in x.iter() {
            out = (out << 1) | ((xi >> b) & 1) as u128;
        }
    }
    out
}

/// Apply a token order to an (N, d) tensor: `out[pos] = x[order[pos]]`.
pub fn permute_rows(x: &Tensor, order: &[usize]) -> Tensor {
    assert_eq!(x.ndim(), 2);
    assert_eq!(x.dim(0), order.len());
    let d = x.dim(1);
    let mut out = Tensor::zeros(&[order.len(), d]);
    for (pos, &src) in order.iter().enumerate() {
        out.row_mut(pos).copy_from_slice(x.row(src));
    }
    out
}

/// Inverse of `order`: `inv[order[pos]] = pos`.
pub fn invert_order(order: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; order.len()];
    for (pos, &src) in order.iter().enumerate() {
        inv[src] = pos;
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    #[test]
    fn gilbert_visits_every_cell_once() {
        Cases::standard(801).check(|rng| {
            let t = rng.range(1, 6);
            let h = rng.range(1, 9);
            let w = rng.range(1, 9);
            let path = gilbert3d(t, h, w);
            if path.len() != t * h * w {
                return Err(format!("len {} != {}", path.len(), t * h * w));
            }
            let mut seen = vec![false; t * h * w];
            for &(a, b, c) in &path {
                if a >= t || b >= h || c >= w {
                    return Err(format!("out of bounds ({a},{b},{c})"));
                }
                let i = (a * h + b) * w + c;
                if seen[i] {
                    return Err(format!("revisit ({a},{b},{c})"));
                }
                seen[i] = true;
            }
            Ok(())
        });
    }

    #[test]
    fn hilbert_steps_are_adjacent_on_pow2_cubes() {
        for &(t, h, w) in &[(2usize, 2usize, 2usize), (4, 4, 4), (8, 8, 8)] {
            let path = gilbert3d(t, h, w);
            assert_eq!(path.len(), t * h * w);
            for win in path.windows(2) {
                let (a, b) = (win[0], win[1]);
                let dist = a.0.abs_diff(b.0) + a.1.abs_diff(b.1) + a.2.abs_diff(b.2);
                assert_eq!(dist, 1, "non-adjacent step {a:?} -> {b:?} in {t}x{h}x{w}");
            }
        }
    }

    #[test]
    fn paper_figure_grid_1x6x6_is_local() {
        // Fig. 5's 1×6×6 example: ragged grids are the restriction of the
        // enclosing cube's curve — steps stay short on average (vs ~4.0 for
        // a random order on this grid).
        let path = gilbert3d(1, 6, 6);
        assert_eq!(path.len(), 36);
        let total: usize = path
            .windows(2)
            .map(|w| w[0].0.abs_diff(w[1].0) + w[0].1.abs_diff(w[1].1) + w[0].2.abs_diff(w[1].2))
            .sum();
        let mean = total as f64 / 35.0;
        assert!(mean < 1.5, "mean step distance {mean}");
    }

    #[test]
    fn hilbert_index_is_bijective_on_cube() {
        let bits = 3;
        let mut seen = std::collections::BTreeSet::new();
        for a in 0..8u32 {
            for b in 0..8u32 {
                for c in 0..8u32 {
                    assert!(seen.insert(hilbert_index([a, b, c], bits)));
                }
            }
        }
        assert_eq!(seen.len(), 512);
        assert_eq!(*seen.iter().next_back().unwrap(), 511);
    }

    #[test]
    fn all_orders_are_bijections() {
        Cases::standard(802).check(|rng| {
            let t = rng.range(1, 5);
            let h = rng.range(1, 7);
            let w = rng.range(1, 7);
            for perm in Permutation::all() {
                let order = token_order(perm, t, h, w, 42);
                let mut seen = vec![false; t * h * w];
                for &i in &order {
                    if seen[i] {
                        return Err(format!("{}: duplicate {i}", perm.name()));
                    }
                    seen[i] = true;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn inverse_composes_to_identity() {
        Cases::standard(803).check(|rng| {
            let n = rng.range(1, 200);
            let order = rng.permutation(n);
            let inv = invert_order(&order);
            for pos in 0..n {
                if inv[order[pos]] != pos {
                    return Err("inv(order) != id".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn permute_then_unpermute_restores_tensor() {
        let mut rng = Pcg::seeded(17);
        let x = Tensor::randn(&[24, 4], &mut rng);
        let order = token_order(Permutation::HilbertCurve, 2, 3, 4, 0);
        let y = permute_rows(&x, &order);
        let back = permute_rows(&y, &invert_order(&order));
        assert_eq!(x, back);
    }

    #[test]
    fn row_major_is_identity_order() {
        let order = token_order(Permutation::RowMajor, 2, 3, 4, 0);
        assert_eq!(order, (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn time_major_groups_time_contiguously() {
        let order = token_order(Permutation::TimeMajor, 3, 2, 2, 0);
        // first 3 entries share (h,w)=(0,0) across t=0,1,2
        assert_eq!(&order[..3], &[0, 4, 8]);
    }

    #[test]
    fn column_major_groups_columns() {
        let order = token_order(Permutation::ColumnMajor, 1, 3, 2, 0);
        // w=0 column first: (0,0,0),(0,1,0),(0,2,0) => 0,2,4
        assert_eq!(&order[..3], &[0, 2, 4]);
    }
}
