//! Per-layer hyper-parameter tables with JSON persistence.
//!
//! The paper tunes (τ, θ, λ) per attention layer (§3.6, §4.3 "setting
//! different hyperparameters for each layer and head is necessary"). This
//! module stores a model's full table and round-trips it through the
//! repo's JSON substrate so the Rust coordinator can load tuned configs
//! produced by `sparge tune`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::kernel::SpargeParams;

/// Hyper-parameters for every attention layer of one model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpargeConfig {
    pub model: String,
    /// Error bounds used during tuning (provenance).
    pub l1: f64,
    pub l2: f64,
    pub layers: Vec<SpargeParams>,
}

impl ModelSpargeConfig {
    /// Uniform config (same params for all layers).
    pub fn uniform(model: &str, n_layers: usize, params: SpargeParams, l1: f64, l2: f64) -> Self {
        ModelSpargeConfig { model: model.to_string(), l1, l2, layers: vec![params; n_layers] }
    }

    /// Params for layer `i` (clamped to the last entry, so a shorter table
    /// still covers deeper models).
    pub fn layer(&self, i: usize) -> &SpargeParams {
        &self.layers[i.min(self.layers.len() - 1)]
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("l1", Json::num(self.l1)),
            ("l2", Json::num(self.l2)),
            (
                "layers",
                Json::arr(self.layers.iter().map(|p| {
                    Json::obj(vec![
                        ("tau", Json::num(p.tau as f64)),
                        ("theta", Json::num(p.theta as f64)),
                        ("lambda", p.lambda.map(|l| Json::num(l as f64)).unwrap_or(Json::Null)),
                        ("quant", Json::Bool(p.quant)),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let model = j.get("model").and_then(|v| v.as_str()).context("config: missing 'model'")?.to_string();
        let l1 = j.get("l1").and_then(|v| v.as_f64()).context("config: missing 'l1'")?;
        let l2 = j.get("l2").and_then(|v| v.as_f64()).context("config: missing 'l2'")?;
        let layers_json = j.get("layers").and_then(|v| v.as_arr()).context("config: missing 'layers'")?;
        if layers_json.is_empty() {
            bail!("config: empty layers");
        }
        let mut layers = Vec::with_capacity(layers_json.len());
        for (i, lj) in layers_json.iter().enumerate() {
            let tau =
                lj.get("tau").and_then(|v| v.as_f64()).with_context(|| format!("layer {i}: tau"))? as f32;
            let theta = lj.get("theta").and_then(|v| v.as_f64()).with_context(|| format!("layer {i}: theta"))?
                as f32;
            let lambda = match lj.get("lambda") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_f64().with_context(|| format!("layer {i}: lambda"))? as f32),
            };
            let quant = lj.get("quant").and_then(|v| v.as_bool()).unwrap_or(false);
            layers.push(SpargeParams { tau, theta, lambda, quant });
        }
        Ok(ModelSpargeConfig { model, l1, l2, layers })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().dump()).with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ModelSpargeConfig {
        ModelSpargeConfig {
            model: "llama-proxy".into(),
            l1: 0.08,
            l2: 0.09,
            layers: vec![
                SpargeParams { tau: 0.9, theta: 0.4, lambda: Some(-5.0), quant: true },
                SpargeParams { tau: 0.8, theta: 0.2, lambda: None, quant: false },
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let cfg = sample();
        let j = cfg.to_json();
        let back = ModelSpargeConfig::from_json(&Json::parse(&j.dump()).unwrap()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn file_roundtrip() {
        let cfg = sample();
        let dir = std::env::temp_dir().join("sparge_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        cfg.save(&path).unwrap();
        let back = ModelSpargeConfig::load(&path).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn layer_clamps_to_last() {
        let cfg = sample();
        assert_eq!(cfg.layer(0), &cfg.layers[0]);
        assert_eq!(cfg.layer(99), &cfg.layers[1]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(ModelSpargeConfig::from_json(&Json::parse("{}").unwrap()).is_err());
        let missing_layers = r#"{"model":"m","l1":0.1,"l2":0.2,"layers":[]}"#;
        assert!(ModelSpargeConfig::from_json(&Json::parse(missing_layers).unwrap()).is_err());
    }
}
