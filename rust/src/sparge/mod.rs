//! The paper's contribution: SpargeAttn — universal training-free sparse +
//! quantized attention, expressed over the unified tiled pipeline.
//!
//! - [`predict`]: stage-1 sparse mask prediction via selective token
//!   compression (§3.2–3.3); its `M_g` drives the pipeline through a
//!   `MaskFilter` (`crate::attention::pipeline`);
//! - [`kernel`]: thin compositions over `run_tiled` — the f32 and
//!   SageAttention-INT8 (`QuantScoreKernel`, §3.5) score paths under the
//!   stage-1 mask + stage-2 λ filter (§3.4), serial or parallel over
//!   query-block rows;
//! - [`hilbert`]: HilbertCurve token permutation for visual models (§3.7);
//! - [`tune`]: per-layer hyper-parameter grid search (§3.6);
//! - [`config`]: per-layer parameter tables with JSON persistence;
//! - [`metrics`]: relative-L1 / sparsity / similarity metrics (§4.1).
//!
//! Extension recipe: a new mask policy (a new baseline) is a new
//! `BlockFilter` impl plus a mask constructor in `crate::baselines`; a new
//! score precision is a new `ScoreKernel` impl like [`QuantScoreKernel`].
//! Neither adds a loop.

pub mod config;
pub mod hilbert;
pub mod kernel;
pub mod metrics;
pub mod predict;
pub mod tune;

pub use config::ModelSpargeConfig;
pub use kernel::{
    sparge_attention, sparge_attention_heads, sparge_attention_threads, sparse_flash,
    sparse_flash_threads, QuantScoreKernel, SpargeOutput, SpargeParams,
};
pub use predict::{predict, PredictParams, Prediction};
pub use tune::{tune_layer, CalibSample, TuneOptions, TuneResult};
