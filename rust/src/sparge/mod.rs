//! The paper's contribution: SpargeAttn — universal training-free sparse +
//! quantized attention.
//!
//! - [`predict`]: stage-1 sparse mask prediction via selective token
//!   compression (§3.2–3.3);
//! - [`kernel`]: the sparse FlashAttention kernel with stage-1 block skips
//!   and the stage-2 sparse warp online softmax (§3.4), plus the
//!   SageAttention INT8 integration (§3.5);
//! - [`hilbert`]: HilbertCurve token permutation for visual models (§3.7);
//! - [`tune`]: per-layer hyper-parameter grid search (§3.6);
//! - [`config`]: per-layer parameter tables with JSON persistence;
//! - [`metrics`]: relative-L1 / sparsity / similarity metrics (§4.1).

pub mod config;
pub mod hilbert;
pub mod kernel;
pub mod metrics;
pub mod predict;
pub mod tune;

pub use config::ModelSpargeConfig;
pub use kernel::{sparge_attention, sparge_attention_heads, sparse_flash, SpargeOutput, SpargeParams};
pub use predict::{predict, PredictParams, Prediction};
pub use tune::{tune_layer, CalibSample, TuneOptions, TuneResult};
