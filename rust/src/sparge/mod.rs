//! The paper's contribution: SpargeAttn — universal training-free sparse +
//! quantized attention, expressed over the unified attention API
//! (`crate::attention::AttnEngine`) and tiled pipeline.
//!
//! - [`predict`]: stage-1 sparse mask prediction via selective token
//!   compression (§3.2–3.3); its `M_g` drives the pipeline through a
//!   `MaskFilter` (`crate::attention::pipeline`). [`KPool`] is the
//!   incremental (per-appended-row) form of the K-side pooling used by
//!   decode sessions, and [`predict::predict_decode_row`] the one-row
//!   decode-step prediction;
//! - [`kernel`]: the SageAttention-INT8 score path ([`QuantScoreKernel`],
//!   §3.5), [`SpargeParams`], and the deprecated free-function shims the
//!   engine builder replaces (see the migration table in
//!   `crate::attention`);
//! - [`hilbert`]: HilbertCurve token permutation for visual models (§3.7);
//! - [`tune`]: per-layer hyper-parameter grid search (§3.6);
//! - [`config`]: per-layer parameter tables with JSON persistence;
//! - [`metrics`]: relative-L1 / sparsity / similarity metrics (§4.1).
//!
//! Extension recipe: a new mask policy (a new baseline) is a new
//! `BlockFilter` impl plus a mask constructor in `crate::baselines` driven
//! through `SparsityPolicy::External`; a new score precision is a new
//! `ScoreKernel` impl like [`QuantScoreKernel`]. Neither adds a loop.

pub mod config;
pub mod hilbert;
pub mod kernel;
pub mod metrics;
pub mod predict;
pub mod tune;

pub use config::ModelSpargeConfig;
#[allow(deprecated)]
pub use kernel::{
    sparge_attention, sparge_attention_heads, sparge_attention_threads, sparse_flash,
    sparse_flash_threads, QuantScoreKernel, SpargeOutput, SpargeParams,
};
pub use predict::{predict, predict_pooled, KPool, PredictParams, Prediction};
pub use tune::{tune_layer, CalibSample, TuneOptions, TuneResult};
