//! Stage-1 sparse mask prediction (paper §3.2–3.3, Alg. 1 lines 4–6).
//!
//! Pipeline:
//! 1. compress each Q/K block to its mean token (`mean(Q_i, axis=0)`);
//! 2. per-block mean cosine self-similarity `CosSim`;
//! 3. compressed score map `Ŝ = q kᵀ`, with columns of non-self-similar
//!    (fix) K blocks set to −∞;
//! 4. row softmax → `P̂`; per-row `TopCdf(τ)` selects the block set whose
//!    cumulative probability reaches τ;
//! 5. rows of fix Q blocks and columns of fix K blocks are forced to 1.

use crate::attention::types::{AttnConfig, BlockMask};
use crate::tensor::microkernel::Backend;
use crate::tensor::{matmul, ops, Tensor};

/// Output of the prediction pass.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// The stage-1 block mask `M_g`.
    pub mask: BlockMask,
    /// Per-Q-block mean self-similarity `s_q`.
    pub sim_q: Vec<f32>,
    /// Per-K-block mean self-similarity `s_k`.
    pub sim_k: Vec<f32>,
    /// The compressed attention map P̂ (n_qblocks × n_kblocks) for analysis
    /// (Fig. 2 pattern dumps).
    pub p_hat: Tensor,
}

/// Hyper-parameters of the prediction stage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredictParams {
    /// CDF coverage threshold τ ∈ (0,1).
    pub tau: f32,
    /// Self-similarity threshold θ ∈ (−1,1).
    pub theta: f32,
}

impl Default for PredictParams {
    fn default() -> Self {
        PredictParams { tau: 0.9, theta: 0.5 }
    }
}

/// Mean pairwise cosine similarity of the rows of `block` —
/// `CosSim(X) = mean(XXᵀ / |max(XXᵀ)|)` per the paper. Rows are
/// L2-normalized first so XXᵀ entries are true cosines in [−1, 1]
/// (`|max|` normalization then is a no-op but guards degenerate blocks).
pub fn cos_sim(block: &[f32], rows: usize, d: usize) -> f32 {
    let mut normed = Vec::new();
    cos_sim_with(block, rows, d, &mut normed)
}

/// [`cos_sim`] with a caller-provided normalization buffer, so the
/// per-token pooling update ([`KPool::append_row`] on the decode hot
/// path) allocates nothing once the buffer holds one block's rows.
/// Bitwise-identical to [`cos_sim`].
pub fn cos_sim_with(block: &[f32], rows: usize, d: usize, normed: &mut Vec<f32>) -> f32 {
    cos_sim_with_backend(Backend::select(), block, rows, d, normed)
}

/// [`cos_sim_with`] on an explicit microkernel backend: the Gram entries
/// run through [`Backend::dot`] (fixed-order tier — bitwise-identical on
/// every backend), while the row norms stay the scalar sequential
/// [`ops::norm`] sum (a *different* evaluation order than `dot`; routing
/// them through the lane-chunked kernel would change bits). An engine
/// pins one backend per [`KPool`], but because every kernel used here is
/// fixed-order, the result is the same bits regardless of the handle.
pub fn cos_sim_with_backend(
    mk: Backend,
    block: &[f32],
    rows: usize,
    d: usize,
    normed: &mut Vec<f32>,
) -> f32 {
    debug_assert_eq!(block.len(), rows * d);
    if rows <= 1 {
        return 1.0;
    }
    // normalize rows
    normed.clear();
    normed.resize(rows * d, 0.0);
    for i in 0..rows {
        let row = &block[i * d..(i + 1) * d];
        let n = ops::norm(row);
        let inv = if n > 0.0 { 1.0 / n } else { 0.0 };
        for (o, &v) in normed[i * d..(i + 1) * d].iter_mut().zip(row) {
            *o = v * inv;
        }
    }
    // mean of the full Gram matrix (including diagonal, as in the paper's
    // formula mean(XXᵀ)).
    let mut sum = 0f64;
    let mut maxabs = 0f32;
    for i in 0..rows {
        for j in 0..rows {
            let g = mk.dot(&normed[i * d..(i + 1) * d], &normed[j * d..(j + 1) * d]);
            sum += g as f64;
            maxabs = maxabs.max(g.abs());
        }
    }
    if maxabs == 0.0 {
        return 1.0;
    }
    (sum / (rows * rows) as f64) as f32 / maxabs
}

/// Compress each block of `x` (N×d) into its mean token; returns
/// (compressed tokens as (n_blocks × d), per-block self-similarity).
pub fn compress_blocks(x: &Tensor, block_rows: usize) -> (Tensor, Vec<f32>) {
    assert_eq!(x.ndim(), 2);
    let (n, d) = (x.dim(0), x.dim(1));
    let nb = n.div_ceil(block_rows);
    let mut tokens = Tensor::zeros(&[nb, d]);
    let mut sims = Vec::with_capacity(nb);
    for b in 0..nb {
        let r0 = b * block_rows;
        let r1 = (r0 + block_rows).min(n);
        let block = &x.data()[r0 * d..r1 * d];
        let rows = r1 - r0;
        let mean = {
            let sub = Tensor::from_vec(&[rows, d], block.to_vec());
            ops::mean_axis0(&sub)
        };
        tokens.row_mut(b).copy_from_slice(&mean);
        sims.push(cos_sim(block, rows, d));
    }
    (tokens, sims)
}

/// The paper's TopCdf: "select the positions of the top values whose
/// cumulative sum *reaches* τ·ΣP̂[i]" — i.e. the minimal prefix of the
/// descending-sorted row whose mass ≥ τ·total, *including* the element
/// that crosses the threshold. (The paper's torch pseudocode
/// `cusum ≤ τ·sum` excludes the crossing element; taken literally that
/// drops up to half the attention mass when it concentrates in few blocks
/// — e.g. two blocks at 0.50/0.48 with τ=0.95 would keep only one — so we
/// implement the inclusive reading the prose describes.)
pub fn top_cdf(p_row: &[f32], tau: f32) -> Vec<bool> {
    let mut idx = Vec::new();
    let n_sel = top_cdf_indices(p_row, tau, &mut idx);
    let mut out = vec![false; p_row.len()];
    for &i in &idx[..n_sel] {
        out[i] = true;
    }
    out
}

/// [`top_cdf`] into a caller-provided index buffer: `idx` ends up holding
/// all indices sorted by descending probability and the returned count is
/// the length of the selected prefix (`idx[..n_sel]` are the kept
/// blocks). Selects exactly the same set as [`top_cdf`] — the hand-rolled
/// insertion sort is *stable* with the same descending comparator
/// (NaN-tie semantics included), so the visiting order and the running
/// cumsum are bit-identical to the `sort_by` path — while allocating
/// nothing once `idx` has reached the row length (the decode hot path;
/// `Vec::sort_by` buys runs of scratch per call).
pub fn top_cdf_indices(p_row: &[f32], tau: f32, idx: &mut Vec<usize>) -> usize {
    idx.clear();
    idx.extend(0..p_row.len());
    // stable insertion sort, descending: element `cur` moves left past
    // `prev` only when prev's probability is *strictly* smaller (ties —
    // NaN included — keep their original order, matching
    // `partial_cmp(..).unwrap_or(Equal)` under a stable sort). Row
    // lengths here are block counts (tens), where insertion sort is also
    // simply fast.
    for i in 1..idx.len() {
        let cur = idx[i];
        let mut j = i;
        while j > 0 && p_row[idx[j - 1]] < p_row[cur] {
            idx[j] = idx[j - 1];
            j -= 1;
        }
        idx[j] = cur;
    }
    let total: f32 = p_row.iter().sum();
    let budget = tau * total;
    let mut cum = 0f32;
    let mut n_sel = 0;
    for &i in idx.iter() {
        n_sel += 1;
        cum += p_row[i];
        if cum >= budget {
            break;
        }
    }
    n_sel
}

/// Run the full stage-1 prediction for one attention head.
///
/// `causal` restricts both P̂'s softmax support and the mask to the block
/// lower triangle (blocks fully above the diagonal are never computed, so
/// they are outside the mask domain).
pub fn predict(q: &Tensor, k: &Tensor, cfg: &AttnConfig, params: &PredictParams) -> Prediction {
    let (kt, sim_k) = compress_blocks(k, cfg.bk);
    predict_pooled(q, &kt, &sim_k, cfg, params)
}

/// [`predict`] from an already-pooled K side: block mean tokens `kt`
/// (n_kblocks × d) and per-block self-similarities `sim_k`. This is the
/// session path — an `AttnSession` maintains exactly this state
/// incrementally (see [`KPool`]) and reuses it here instead of
/// re-compressing the whole K cache. With `kt`/`sim_k` from
/// [`compress_blocks`] the result is identical to [`predict`].
pub fn predict_pooled(
    q: &Tensor,
    kt: &Tensor,
    sim_k: &[f32],
    cfg: &AttnConfig,
    params: &PredictParams,
) -> Prediction {
    let (qt, sim_q) = compress_blocks(q, cfg.bq);
    let tm = qt.dim(0);
    let tn = kt.dim(0);
    let d = q.dim(1);
    let scale = cfg.scale_for(d);

    // Ŝ = q kᵀ (scaled like the real scores so λ/τ operate on the same
    // scale); fix-K columns → −∞ before softmax.
    let mut s_hat = matmul::matmul_nt(&qt, kt);
    s_hat.scale(scale);
    for j in 0..tn {
        if sim_k[j] < params.theta {
            for i in 0..tm {
                *s_hat.at2_mut(i, j) = f32::NEG_INFINITY;
            }
        }
    }
    if cfg.causal {
        // Block (i,j) is outside the causal domain when its *first* key row
        // is past the q-block's last query row's absolute position
        // (`cfg.row_offset` shifts chunked-prefill query rows).
        for i in 0..tm {
            let q_last = cfg.row_offset + ((i + 1) * cfg.bq).min(q.dim(0)) - 1;
            for j in 0..tn {
                if j * cfg.bk > q_last {
                    *s_hat.at2_mut(i, j) = f32::NEG_INFINITY;
                }
            }
        }
    }
    let p_hat = ops::softmax_rows(&s_hat);

    let mut mask = BlockMask::new_all(tm, tn, false);
    for i in 0..tm {
        let sel = top_cdf(p_hat.row(i), params.tau);
        for (j, &on) in sel.iter().enumerate() {
            if on {
                mask.set(i, j, true);
            }
        }
    }
    // Fix blocks are never skipped (Eq. 5).
    for i in 0..tm {
        if sim_q[i] < params.theta {
            mask.set_row(i, true);
        }
    }
    for j in 0..tn {
        if sim_k[j] < params.theta {
            mask.set_col(j, true);
        }
    }
    // Causal: clear mask bits outside the causal domain again (fix-block
    // row/col fills may have re-set them); the kernel never visits them.
    if cfg.causal {
        for i in 0..tm {
            let q_last = cfg.row_offset + ((i + 1) * cfg.bq).min(q.dim(0)) - 1;
            for j in 0..tn {
                if j * cfg.bk > q_last {
                    mask.set(i, j, false);
                }
            }
        }
    }
    Prediction { mask, sim_q, sim_k: sim_k.to_vec(), p_hat }
}

/// One decode-step stage-1 prediction: the single query row scored against
/// the pooled K block means. The q "block" is the row itself (a one-row
/// block has self-similarity 1), so only the fix-K rule and TopCdf apply.
/// Returns a 1 × n_kblocks mask; `scale` is the engine's softmax scale.
pub fn predict_decode_row(
    q_row: &[f32],
    kt: &Tensor,
    sim_k: &[f32],
    scale: f32,
    params: &PredictParams,
) -> BlockMask {
    let mut mask = BlockMask::new_all(0, 0, false);
    let (mut s_hat, mut p, mut idx) = (Vec::new(), Vec::new(), Vec::new());
    predict_decode_row_into(q_row, kt.data(), sim_k, scale, params, &mut mask, &mut s_hat, &mut p, &mut idx);
    mask
}

/// [`predict_decode_row`] in place: the mask is reset and rebuilt rather
/// than returned, `kt` is the flat (n_kblocks × d) block-mean buffer
/// ([`KPool::means_into`]), and `s_hat`/`p`/`idx` are reusable scratch
/// (session [`crate::util::threadpool::Workspace`] arenas on the serving
/// path). Bit-identical mask to the allocating wrapper — every float op
/// runs in the same order — and allocation-free once all four buffers
/// have reached the cache's block count.
#[allow(clippy::too_many_arguments)]
pub fn predict_decode_row_into(
    q_row: &[f32],
    kt: &[f32],
    sim_k: &[f32],
    scale: f32,
    params: &PredictParams,
    mask: &mut BlockMask,
    s_hat: &mut Vec<f32>,
    p: &mut Vec<f32>,
    idx: &mut Vec<usize>,
) {
    let tn = sim_k.len();
    let d = q_row.len();
    debug_assert_eq!(kt.len(), tn * d);
    s_hat.clear();
    s_hat.resize(tn, 0.0);
    for (j, sv) in s_hat.iter_mut().enumerate() {
        *sv = matmul::dot(q_row, &kt[j * d..(j + 1) * d]) * scale;
    }
    for (sv, &sim) in s_hat.iter_mut().zip(sim_k) {
        if sim < params.theta {
            *sv = f32::NEG_INFINITY;
        }
    }
    // stable row softmax (all blocks are in the causal domain of the last
    // row, so no further masking applies)
    let m = s_hat.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    p.clear();
    p.resize(tn, 0.0);
    if m > f32::NEG_INFINITY {
        let mut sum = 0f32;
        for (pv, &sv) in p.iter_mut().zip(s_hat.iter()) {
            let e = if sv == f32::NEG_INFINITY { 0.0 } else { (sv - m).exp() };
            *pv = e;
            sum += e;
        }
        if sum > 0.0 {
            let inv = 1.0 / sum;
            for pv in p.iter_mut() {
                *pv *= inv;
            }
        }
    }
    let n_sel = top_cdf_indices(p, params.tau, idx);
    mask.reset(1, tn, false);
    for &j in &idx[..n_sel] {
        mask.set(0, j, true);
    }
    // Fix blocks are never skipped (Eq. 5); the one-row q block fires the
    // fix-Q rule only for θ > 1.
    for (j, &sim) in sim_k.iter().enumerate() {
        if sim < params.theta {
            mask.set(0, j, true);
        }
    }
    if 1.0 < params.theta {
        mask.set_row(0, true);
    }
}

/// Incrementally-maintained K-side pooling state for stage-1 prediction:
/// per-block mean-token sums and self-similarities, grown row by row so a
/// decode step never re-runs [`compress_blocks`] over the whole cache.
///
/// Bitwise contract: [`KPool::means`] and [`KPool::sims`] equal a
/// from-scratch `compress_blocks` of the same rows exactly — the per-block
/// mean accumulates rows in arrival order like `mean_axis0`, and the tail
/// block's `cos_sim` is recomputed with the same function over the same
/// slice. The counters let callers assert the update discipline: sessions
/// require `full_recomputes` to stay flat across decode steps.
///
/// The pooling loops dispatch through a pinned [`Backend`] handle
/// ([`KPool::with_microkernel`]): block-sum accumulation runs
/// [`Backend::sum_rows_acc`] and the self-similarity Gram entries run
/// [`Backend::dot`] — both in the fixed-order kernel tier, so every
/// backend produces the same bits (property-tested below).
#[derive(Clone, Debug)]
pub struct KPool {
    bk: usize,
    d: usize,
    /// Microkernel backend for the pooling loops (fixed-order tier only,
    /// so the choice never changes bits).
    mk: Backend,
    /// Per-block running column sums, flat (n_blocks × d).
    sums: Vec<f32>,
    /// Rows accumulated per block.
    rows: Vec<usize>,
    /// Per-block self-similarity.
    sims: Vec<f32>,
    /// Reusable row-normalization scratch for [`cos_sim_with`], so the
    /// per-token tail-block similarity refresh allocates nothing once it
    /// holds one full block (high-water `bk × d`).
    scratch: Vec<f32>,
    /// Full scans over the whole input (the prefill bulk [`KPool::build`],
    /// or an [`KPool::extend`] that started from an empty pool).
    pub full_recomputes: usize,
    /// Single-row incremental updates (decode appends).
    pub incremental_updates: usize,
    /// Blockwise multi-row extensions (chunked-prefill appends) that only
    /// scanned the new rows plus the partially-filled boundary block.
    pub chunk_extends: usize,
}

impl KPool {
    pub fn new(bk: usize, d: usize) -> KPool {
        assert!(bk > 0 && d > 0, "KPool needs bk > 0 and d > 0");
        KPool {
            bk,
            d,
            mk: Backend::select(),
            sums: Vec::new(),
            rows: Vec::new(),
            sims: Vec::new(),
            scratch: Vec::new(),
            full_recomputes: 0,
            incremental_updates: 0,
            chunk_extends: 0,
        }
    }

    /// Pin the microkernel backend the pooling loops dispatch through
    /// (engines pass their own resolved handle so pooling and scoring
    /// agree). Bitwise-neutral: every kernel the pool uses is in the
    /// fixed-order tier.
    pub fn with_microkernel(mut self, mk: Backend) -> KPool {
        self.mk = mk;
        self
    }

    pub fn n_blocks(&self) -> usize {
        self.rows.len()
    }

    /// Reserve per-block state for a cache of `rows` rows, so a session
    /// growing its KV cache in amortized block-multiple steps grows the
    /// pool's sums/rows/sims in the same strides instead of leaving each
    /// `Vec` to reallocate on its own schedule.
    pub fn reserve_rows(&mut self, rows: usize) {
        let blocks = rows.div_ceil(self.bk);
        self.sums.reserve_exact((blocks * self.d).saturating_sub(self.sums.len()));
        self.rows.reserve_exact(blocks.saturating_sub(self.rows.len()));
        self.sims.reserve_exact(blocks.saturating_sub(self.sims.len()));
    }

    /// Bulk-build from all rows of `k` (pool must be empty): one full
    /// scan, equivalent to `compress_blocks(k, bk)`.
    pub fn build(&mut self, k: &Tensor) {
        assert!(self.rows.is_empty(), "KPool::build on a non-empty pool");
        assert_eq!(k.dim(1), self.d, "KPool::build head dim");
        let n = k.dim(0);
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + self.bk).min(n);
            let base = self.sums.len();
            self.sums.resize(base + self.d, 0.0);
            self.mk.sum_rows_acc(
                &k.data()[r0 * self.d..r1 * self.d],
                &mut self.sums[base..],
                r1 - r0,
                self.d,
            );
            self.rows.push(r1 - r0);
            let s = cos_sim_with_backend(
                self.mk,
                &k.data()[r0 * self.d..r1 * self.d],
                r1 - r0,
                self.d,
                &mut self.scratch,
            );
            self.sims.push(s);
            r0 = r1;
        }
        self.full_recomputes += 1;
    }

    /// Blockwise multi-row extension for chunked prefill: bring the pool
    /// from `rows_before` rows up to `cache.len()/d` rows, where `cache`
    /// is the **full** K cache (old rows followed by the new chunk). Only
    /// the partially-filled boundary block and the new rows are scanned;
    /// earlier full blocks are untouched.
    ///
    /// Bitwise contract: sums accumulate rows in arrival order exactly
    /// like [`KPool::build`] (one block at a time, rows ascending), and
    /// every touched block's self-similarity is recomputed with
    /// [`cos_sim`] over the block's current rows — so after any sequence
    /// of `build`/`extend`/`append_row` calls, [`KPool::means`] and
    /// [`KPool::sims`] equal a from-scratch [`compress_blocks`] of the
    /// same rows exactly. Counter discipline: an extend from an empty
    /// pool is the bulk build (`full_recomputes`); otherwise it counts
    /// one `chunk_extends`.
    pub fn extend(&mut self, rows_before: usize, cache: &[f32]) {
        assert_eq!(cache.len() % self.d, 0, "KPool::extend cache dim");
        let total = cache.len() / self.d;
        debug_assert_eq!(self.rows.iter().sum::<usize>(), rows_before, "pool out of sync with cache");
        assert!(total > rows_before, "KPool::extend needs new rows");
        let from_empty = self.rows.is_empty();
        let mut r = rows_before;
        // top up the partially-filled boundary block first
        if let Some(&last) = self.rows.last() {
            if last < self.bk {
                let b = self.rows.len() - 1;
                let r1 = (b * self.bk + self.bk).min(total);
                self.mk.sum_rows_acc(
                    &cache[r * self.d..r1 * self.d],
                    &mut self.sums[b * self.d..(b + 1) * self.d],
                    r1 - r,
                    self.d,
                );
                self.rows[b] = r1 - b * self.bk;
                let s = cos_sim_with_backend(
                    self.mk,
                    &cache[b * self.bk * self.d..r1 * self.d],
                    self.rows[b],
                    self.d,
                    &mut self.scratch,
                );
                self.sims[b] = s;
                r = r1;
            }
        }
        // then whole fresh blocks (the last may be partial)
        while r < total {
            let r1 = (r + self.bk).min(total);
            let base = self.sums.len();
            self.sums.resize(base + self.d, 0.0);
            self.mk.sum_rows_acc(
                &cache[r * self.d..r1 * self.d],
                &mut self.sums[base..],
                r1 - r,
                self.d,
            );
            self.rows.push(r1 - r);
            let s = cos_sim_with_backend(
                self.mk,
                &cache[r * self.d..r1 * self.d],
                r1 - r,
                self.d,
                &mut self.scratch,
            );
            self.sims.push(s);
            r = r1;
        }
        if from_empty {
            self.full_recomputes += 1;
        } else {
            self.chunk_extends += 1;
        }
    }

    /// Incrementally append one row. `tail` must be the raw rows of the
    /// block the new row lands in, *including* the new row (the caller —
    /// the session — slices it out of its KV cache); only that block's
    /// sum and self-similarity are touched.
    pub fn append_row(&mut self, row: &[f32], tail: &[f32]) {
        assert_eq!(row.len(), self.d, "KPool::append_row dim");
        let open_new = self.rows.last().map(|&r| r == self.bk).unwrap_or(true);
        if open_new {
            self.sums.extend_from_slice(row);
            self.rows.push(1);
            self.sims.push(cos_sim(row, 1, self.d));
        } else {
            let b = self.rows.len() - 1;
            *self.rows.last_mut().unwrap() += 1;
            let rows = self.rows[b];
            self.mk.sum_rows_acc(row, &mut self.sums[b * self.d..(b + 1) * self.d], 1, self.d);
            debug_assert_eq!(tail.len(), rows * self.d, "tail slice must cover the block incl. the new row");
            let s = cos_sim_with_backend(self.mk, tail, rows, self.d, &mut self.scratch);
            self.sims[b] = s;
        }
        self.incremental_updates += 1;
    }

    /// Block mean tokens as an (n_blocks × d) tensor — bitwise equal to
    /// `compress_blocks(..).0` over the same rows.
    pub fn means(&self) -> Tensor {
        let mut flat = Vec::new();
        self.means_into(&mut flat);
        Tensor::from_vec(&[self.n_blocks(), self.d], flat)
    }

    /// [`KPool::means`] into a caller-provided flat (n_blocks × d) buffer
    /// — same bits, no allocation once the buffer has reached its
    /// high-water size. The decode hot path stages the pooled K means
    /// through a [`crate::util::threadpool::Workspace`] arena with this.
    pub fn means_into(&self, out: &mut Vec<f32>) {
        let nb = self.n_blocks();
        out.clear();
        out.resize(nb * self.d, 0.0);
        for b in 0..nb {
            let inv = 1.0 / self.rows[b] as f32;
            for (o, &s) in out[b * self.d..(b + 1) * self.d].iter_mut().zip(&self.sums[b * self.d..(b + 1) * self.d])
            {
                *o = s * inv;
            }
        }
    }

    /// Per-block self-similarities — bitwise equal to
    /// `compress_blocks(..).1` over the same rows.
    pub fn sims(&self) -> &[f32] {
        &self.sims
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;
    use crate::util::rng::Pcg;

    fn cfg(bq: usize, bk: usize, causal: bool) -> AttnConfig {
        AttnConfig { bq, bk, causal, scale: None, cw: 2, row_offset: 0 }
    }

    #[test]
    fn cos_sim_identical_rows_is_one() {
        let block = [1.0f32, 2.0, 1.0, 2.0, 1.0, 2.0];
        let s = cos_sim(&block, 3, 2);
        assert!((s - 1.0).abs() < 1e-5, "sim {s}");
    }

    #[test]
    fn cos_sim_orthogonal_rows_is_low() {
        // rows alternate between e0 and e1 → mean gram = 0.5
        let block = [1.0f32, 0.0, 0.0, 1.0];
        let s = cos_sim(&block, 2, 2);
        assert!((s - 0.5).abs() < 1e-5);
    }

    #[test]
    fn cos_sim_opposed_rows_is_negative() {
        let block = [1.0f32, 0.0, -1.0, 0.0];
        let s = cos_sim(&block, 2, 2);
        assert!(s < 0.1, "sim {s}");
    }

    #[test]
    fn cos_sim_single_row_and_zero_block() {
        assert_eq!(cos_sim(&[3.0, 4.0], 1, 2), 1.0);
        assert_eq!(cos_sim(&[0.0; 8], 4, 2), 1.0);
    }

    #[test]
    fn compress_means() {
        let x = Tensor::from_vec(&[4, 2], vec![1., 0., 3., 0., 10., 2., 20., 4.]);
        let (tokens, sims) = compress_blocks(&x, 2);
        assert_eq!(tokens.row(0), &[2.0, 0.0]);
        assert_eq!(tokens.row(1), &[15.0, 3.0]);
        assert_eq!(sims.len(), 2);
    }

    #[test]
    fn compress_ragged_tail() {
        let x = Tensor::from_vec(&[3, 1], vec![1., 2., 6.]);
        let (tokens, _) = compress_blocks(&x, 2);
        assert_eq!(tokens.shape(), &[2, 1]);
        assert_eq!(tokens.at2(0, 0), 1.5);
        assert_eq!(tokens.at2(1, 0), 6.0);
    }

    #[test]
    fn top_cdf_crossing_element_included() {
        // sorted: .5 .3 .2 ; cumsum .5 .8 ; τ=.8 is reached at the second
        // element -> first two selected.
        let sel = top_cdf(&[0.3, 0.5, 0.2], 0.8);
        assert_eq!(sel, vec![true, true, false]);
        // mass split .50/.48/.02: τ=.95 must keep BOTH heavy blocks.
        let sel = top_cdf(&[0.50, 0.48, 0.02], 0.95);
        assert_eq!(sel, vec![true, true, false]);
    }

    #[test]
    fn top_cdf_small_tau_keeps_top1_only() {
        let sel = top_cdf(&[0.9, 0.1], 0.05);
        assert_eq!(sel, vec![true, false]);
    }

    #[test]
    fn top_cdf_tau_one_keeps_all() {
        let sel = top_cdf(&[0.25; 4], 1.0);
        assert!(sel.iter().all(|&b| b));
    }

    #[test]
    fn top_cdf_coverage_invariant() {
        // Property: (a) selected mass reaches τ·total; (b) selection is a
        // minimal prefix of the descending order: dropping the smallest
        // selected element would fall below τ·total; (c) every unselected
        // element is ≤ every selected element.
        Cases::standard(601).check(|rng| {
            let n = rng.range(1, 40);
            let p: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-6).collect();
            let tau = rng.f32();
            let sel = top_cdf(&p, tau);
            let total: f32 = p.iter().sum();
            let picked: f32 = p.iter().zip(&sel).filter(|(_, &s)| s).map(|(&v, _)| v).sum();
            let n_sel = sel.iter().filter(|&&s| s).count();
            if n_sel == 0 {
                return Err("nothing selected".into());
            }
            if picked < tau * total - 1e-4 {
                return Err(format!("coverage {picked} < tau*total {}", tau * total));
            }
            let min_sel =
                p.iter().zip(&sel).filter(|(_, &s)| s).map(|(&v, _)| v).fold(f32::INFINITY, f32::min);
            for (&v, &s) in p.iter().zip(&sel) {
                if !s && v > min_sel + 1e-6 {
                    return Err(format!("unselected {v} > selected min {min_sel}"));
                }
            }
            if n_sel > 1 && picked - min_sel >= tau * total + 1e-4 {
                return Err("selection not minimal".into());
            }
            Ok(())
        });
    }

    #[test]
    fn predict_tau_one_selects_everything_noncausal() {
        let mut rng = Pcg::seeded(21);
        let q = Tensor::randn(&[32, 8], &mut rng);
        let k = Tensor::randn(&[32, 8], &mut rng);
        let pred = predict(&q, &k, &cfg(8, 8, false), &PredictParams { tau: 1.0, theta: -1.0 });
        assert_eq!(pred.mask.count_active(), 16);
    }

    #[test]
    fn predict_fix_blocks_force_rows_and_cols() {
        // Build K whose block 1 is wildly non-self-similar.
        let mut rng = Pcg::seeded(22);
        let q = Tensor::randn(&[16, 4], &mut rng);
        let mut k = Tensor::randn(&[16, 4], &mut rng);
        // make K block 1 rows opposite signs => low self-sim
        for r in 4..8 {
            let sign = if r % 2 == 0 { 1.0 } else { -1.0 };
            for v in k.row_mut(r) {
                *v = sign * (1.0 + v.abs());
            }
        }
        let pred = predict(&q, &k, &cfg(4, 4, false), &PredictParams { tau: 0.1, theta: 0.9 });
        // column(s) with sim_k < theta are fully on
        for (j, &s) in pred.sim_k.iter().enumerate() {
            if s < 0.9 {
                for i in 0..pred.mask.rows {
                    assert!(pred.mask.get(i, j), "fix col {j} not forced at row {i}");
                }
            }
        }
        for (i, &s) in pred.sim_q.iter().enumerate() {
            if s < 0.9 {
                for j in 0..pred.mask.cols {
                    assert!(pred.mask.get(i, j), "fix row {i} not forced at col {j}");
                }
            }
        }
    }

    #[test]
    fn predict_causal_mask_stays_lower_triangular() {
        Cases::standard(602).check(|rng| {
            let n = rng.range(8, 65);
            let q = Tensor::randn(&[n, 8], rng);
            let k = Tensor::randn(&[n, 8], rng);
            let c = cfg(8, 8, true);
            let pred = predict(&q, &k, &c, &PredictParams { tau: rng.f32(), theta: rng.f32() * 2.0 - 1.0 });
            for i in 0..pred.mask.rows {
                let q_last = ((i + 1) * c.bq).min(n) - 1;
                for j in 0..pred.mask.cols {
                    if j * c.bk > q_last && pred.mask.get(i, j) {
                        return Err(format!("causal violation at block ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn predict_every_row_keeps_at_least_one_block() {
        Cases::standard(603).check(|rng| {
            let n = rng.range(4, 80);
            let q = Tensor::randn(&[n, 8], rng);
            let k = Tensor::randn(&[n, 8], rng);
            let c = cfg(rng.range(2, 12), rng.range(2, 12), false);
            let pred = predict(&q, &k, &c, &PredictParams { tau: 0.01, theta: 0.0 });
            for i in 0..pred.mask.rows {
                if (0..pred.mask.cols).all(|j| !pred.mask.get(i, j)) {
                    return Err(format!("row {i} lost all blocks"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kpool_incremental_matches_compress_blocks_bitwise() {
        // Grow a pool row by row; at several snapshot lengths its means and
        // sims must be bit-identical to a from-scratch compress_blocks.
        let mut rng = Pcg::seeded(611);
        let (n, d, bk) = (53, 8, 8); // ragged tail on purpose
        let k = Tensor::randn(&[n, d], &mut rng);
        let mut pool = KPool::new(bk, d);
        for r in 0..n {
            let tail_start = (r / bk) * bk;
            pool.append_row(k.row(r), &k.data()[tail_start * d..(r + 1) * d]);
            if r % 7 == 0 || r + 1 == n {
                let prefix = k.rows(0, r + 1);
                let (tokens, sims) = compress_blocks(&prefix, bk);
                assert_eq!(pool.means(), tokens, "means diverge at row {r}");
                assert_eq!(pool.sims(), &sims[..], "sims diverge at row {r}");
            }
        }
        assert_eq!(pool.full_recomputes, 0);
        assert_eq!(pool.incremental_updates, n);
    }

    #[test]
    fn kpool_is_bitwise_across_backends() {
        // The pooling loops dispatch through Backend::sum_rows_acc and
        // Backend::dot — both fixed-order tier — so a pool grown through
        // any backend must produce the same bits as the portable one,
        // through every growth path (build, extend, append_row).
        Cases::standard(613).check(|rng| {
            let d = rng.range(1, 24);
            let bk = rng.range(1, 9);
            let n0 = rng.range(1, 40);
            let n1 = n0 + rng.range(1, 20);
            let mut flat = Vec::with_capacity(n1 * d);
            for _ in 0..n1 * d {
                flat.push(rng.gauss());
            }
            let k0 = Tensor::from_vec(&[n0, d], flat[..n0 * d].to_vec());
            let mut per_backend: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
            for &mk in Backend::all() {
                let mut pool = KPool::new(bk, d).with_microkernel(mk);
                pool.build(&k0);
                let mid = n0 + (n1 - n0) / 2;
                if mid > n0 {
                    pool.extend(n0, &flat[..mid * d]);
                }
                for r in mid.max(n0)..n1 {
                    let tail_start = (r / bk) * bk;
                    pool.append_row(&flat[r * d..(r + 1) * d], &flat[tail_start * d..(r + 1) * d]);
                }
                per_backend.push((pool.means().data().to_vec(), pool.sims().to_vec()));
            }
            for (means, sims) in &per_backend[1..] {
                if means != &per_backend[0].0 {
                    return Err(format!("means diverge across backends (d={d} bk={bk} n={n1})"));
                }
                if sims != &per_backend[0].1 {
                    return Err(format!("sims diverge across backends (d={d} bk={bk} n={n1})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kpool_build_matches_compress_blocks_and_counts_one_scan() {
        let mut rng = Pcg::seeded(612);
        let (n, d, bk) = (40, 4, 16);
        let k = Tensor::randn(&[n, d], &mut rng);
        let mut pool = KPool::new(bk, d);
        pool.build(&k);
        let (tokens, sims) = compress_blocks(&k, bk);
        assert_eq!(pool.means(), tokens);
        assert_eq!(pool.sims(), &sims[..]);
        assert_eq!(pool.full_recomputes, 1);
        assert_eq!(pool.incremental_updates, 0);
        // subsequent appends stay incremental
        let extra = Tensor::randn(&[1, d], &mut rng);
        let mut all = k.data().to_vec();
        all.extend_from_slice(extra.data());
        let tail_start = (n / bk) * bk;
        pool.append_row(extra.row(0), &all[tail_start * d..(n + 1) * d]);
        assert_eq!(pool.full_recomputes, 1);
        assert_eq!(pool.incremental_updates, 1);
        let full = Tensor::from_vec(&[n + 1, d], all);
        let (tokens, sims) = compress_blocks(&full, bk);
        assert_eq!(pool.means(), tokens);
        assert_eq!(pool.sims(), &sims[..]);
    }

    #[test]
    fn kpool_extend_matches_compress_blocks_bitwise() {
        // Chunked growth: uneven chunk edges, off the bk grid on purpose.
        // After every extend the pool must be bit-identical to a
        // from-scratch compress_blocks of the rows so far, and the counter
        // discipline must hold (first extend = the bulk build, the rest
        // are chunk extends; appends stay incremental afterwards).
        let mut rng = Pcg::seeded(614);
        let (n, d, bk) = (61, 8, 8);
        let k = Tensor::randn(&[n, d], &mut rng);
        let mut pool = KPool::new(bk, d);
        let edges = [0usize, 13, 14, 40, 61];
        for w in edges.windows(2) {
            let (r0, r1) = (w[0], w[1]);
            pool.extend(r0, &k.data()[..r1 * d]);
            let prefix = k.rows(0, r1);
            let (tokens, sims) = compress_blocks(&prefix, bk);
            assert_eq!(pool.means(), tokens, "means diverge at rows {r1}");
            assert_eq!(pool.sims(), &sims[..], "sims diverge at rows {r1}");
        }
        assert_eq!(pool.full_recomputes, 1);
        assert_eq!(pool.chunk_extends, edges.len() - 2);
        assert_eq!(pool.incremental_updates, 0);
        // a decode append after chunked growth stays incremental
        let extra = Tensor::randn(&[1, d], &mut rng);
        let mut all = k.data().to_vec();
        all.extend_from_slice(extra.data());
        let tail_start = (n / bk) * bk;
        pool.append_row(extra.row(0), &all[tail_start * d..(n + 1) * d]);
        assert_eq!(pool.incremental_updates, 1);
        let full = Tensor::from_vec(&[n + 1, d], all);
        let (tokens, sims) = compress_blocks(&full, bk);
        assert_eq!(pool.means(), tokens);
        assert_eq!(pool.sims(), &sims[..]);
    }

    #[test]
    fn predict_pooled_matches_predict() {
        Cases::standard(613).check(|rng| {
            let n = rng.range(8, 80);
            let q = Tensor::randn(&[n, 8], rng);
            let k = Tensor::randn(&[n, 8], rng);
            let c = cfg(rng.range(2, 12), rng.range(2, 12), rng.chance(0.5));
            let params = PredictParams { tau: rng.f32(), theta: rng.f32() - 0.5 };
            let direct = predict(&q, &k, &c, &params);
            let (kt, sim_k) = compress_blocks(&k, c.bk);
            let pooled = predict_pooled(&q, &kt, &sim_k, &c, &params);
            if direct.mask != pooled.mask {
                return Err("pooled predict mask diverges".into());
            }
            Ok(())
        });
    }

    #[test]
    fn predict_decode_row_selects_dominant_block_and_forces_fix_cols() {
        let d = 4;
        // three K blocks with distinct directions; query aligned to block 1
        let kt = Tensor::from_vec(&[3, d], vec![4., 0., 0., 0., 0., 4., 0., 0., 0., 0., 4., 0.]);
        let q = [0f32, 2.0, 0.0, 0.0];
        let sim = [0.9f32, 0.9, 0.9];
        let mask = predict_decode_row(&q, &kt, &sim, 1.0, &PredictParams { tau: 0.5, theta: 0.0 });
        assert!(mask.get(0, 1), "dominant block not selected");
        assert!(!mask.get(0, 0) && !mask.get(0, 2), "small-mass blocks should be dropped at tau=0.5");
        // a fix-K column (low self-similarity) is always kept
        let sim_fix = [0.9f32, 0.9, -0.5];
        let mask = predict_decode_row(&q, &kt, &sim_fix, 1.0, &PredictParams { tau: 0.5, theta: 0.0 });
        assert!(mask.get(0, 2), "fix-K column must be forced on");
        // tau=1 keeps every block
        let mask = predict_decode_row(&q, &kt, &sim, 1.0, &PredictParams { tau: 1.0, theta: 0.0 });
        assert_eq!(mask.count_active(), 3);
    }

    #[test]
    fn predict_decode_row_into_matches_allocating_bitwise() {
        // The pooled in-place variant must reproduce the allocating one
        // bit for bit from arbitrarily stale reusable buffers — the
        // serving loop's per-step masks ride on this.
        Cases::standard(615).check(|rng| {
            let tn = rng.range(1, 24);
            let d = rng.range(1, 33);
            let kt = Tensor::randn(&[tn, d], rng);
            let q: Vec<f32> = rng.gauss_vec(d);
            let sim: Vec<f32> = (0..tn).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let params = PredictParams { tau: rng.f32(), theta: rng.f32() * 2.0 - 1.0 };
            let scale = rng.f32() + 0.1;
            let base = predict_decode_row(&q, &kt, &sim, scale, &params);
            let mut mask = BlockMask::new_all(3, 5, true); // stale shape + bits
            let mut s_hat = vec![9.0f32; 7];
            let mut p = vec![9.0f32; 3];
            let mut idx = vec![42usize; 9];
            predict_decode_row_into(&q, kt.data(), &sim, scale, &params, &mut mask, &mut s_hat, &mut p, &mut idx);
            if mask != base {
                return Err(format!("in-place decode predict diverged at tn={tn} d={d}"));
            }
            Ok(())
        });
    }

    #[test]
    fn top_cdf_indices_matches_top_cdf() {
        Cases::standard(616).check(|rng| {
            let n = rng.range(1, 50);
            let p: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let tau = rng.f32();
            let sel = top_cdf(&p, tau);
            let mut idx = vec![7usize; 3]; // stale
            let n_sel = top_cdf_indices(&p, tau, &mut idx);
            let mut via_idx = vec![false; n];
            for &i in &idx[..n_sel] {
                via_idx[i] = true;
            }
            if via_idx != sel {
                return Err("index variant selected a different block set".into());
            }
            Ok(())
        });
    }

    #[test]
    fn kpool_means_into_matches_means() {
        let mut rng = Pcg::seeded(617);
        let (n, d, bk) = (43, 8, 8);
        let k = Tensor::randn(&[n, d], &mut rng);
        let mut pool = KPool::new(bk, d);
        pool.build(&k);
        let mut flat = vec![1.0f32; 5]; // stale
        pool.means_into(&mut flat);
        assert_eq!(flat.as_slice(), pool.means().data());
    }

    #[test]
    fn locality_raises_selected_diagonal() {
        // Q/K with strong local structure: token t points at direction of
        // its block => diagonal of P̂ dominates; with small τ the mask
        // should prefer the diagonal.
        let n = 64;
        let d = 16;
        let bq = 8;
        let mut q = Tensor::zeros(&[n, d]);
        let mut k = Tensor::zeros(&[n, d]);
        for t in 0..n {
            let b = t / bq;
            q.row_mut(t)[b % d] = 4.0;
            k.row_mut(t)[b % d] = 4.0;
        }
        let pred = predict(&q, &k, &cfg(bq, bq, false), &PredictParams { tau: 0.3, theta: 0.0 });
        for i in 0..pred.mask.rows {
            assert!(pred.mask.get(i, i), "diagonal block ({i},{i}) not selected");
        }
        assert!(pred.mask.sparsity() > 0.5, "sparsity {}", pred.mask.sparsity());
    }
}
