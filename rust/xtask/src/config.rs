//! lint.toml loading — a hand-rolled parser for the TOML subset the
//! config actually uses (`[section]` headers, `key = [ "...", ... ]`
//! string arrays, `#` comments), so the lint crate's dependency set
//! stays at exactly what the AST walk needs (syn).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Parsed rule configuration (see xtask/lint.toml for semantics).
#[derive(Debug, Default, Clone)]
pub struct Config {
    /// unsafe-needs-safety: files allowed to contain `unsafe` at all.
    pub unsafe_allow_files: Vec<String>,
    /// fixed-order-no-fma: `file.rs::fn` entries allowed to fuse.
    pub fma_allow_fns: Vec<String>,
    /// hot-path-no-alloc: declared hot functions (`name` or `Type::name`).
    pub hot_fns: Vec<String>,
    /// hot-path-no-alloc: files where every non-test fn is hot.
    pub hot_files: Vec<String>,
    /// no-raw-thread-spawn: files allowed to spawn/scope threads.
    pub spawn_allow_files: Vec<String>,
    /// serving-no-panic: files in scope.
    pub panic_files: Vec<String>,
    /// serving-no-panic: `fn` / `Type::fn` names exempted (fail-fast startup).
    pub panic_allow_fns: Vec<String>,
}

pub fn load(path: &Path) -> Result<Config> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse(text: &str) -> Result<Config> {
    let raw = parse_sections(text)?;
    let mut cfg = Config::default();
    for (section, keys) in &raw {
        for (key, values) in keys {
            let slot = match (section.as_str(), key.as_str()) {
                ("rules.unsafe-needs-safety", "allow_files") => &mut cfg.unsafe_allow_files,
                ("rules.fixed-order-no-fma", "allow_fns") => &mut cfg.fma_allow_fns,
                ("rules.hot-path-no-alloc", "hot_fns") => &mut cfg.hot_fns,
                ("rules.hot-path-no-alloc", "hot_files") => &mut cfg.hot_files,
                ("rules.no-raw-thread-spawn", "allow_files") => &mut cfg.spawn_allow_files,
                ("rules.serving-no-panic", "files") => &mut cfg.panic_files,
                ("rules.serving-no-panic", "allow_fns") => &mut cfg.panic_allow_fns,
                _ => bail!("unknown config key [{section}] {key}"),
            };
            slot.clone_from(values);
        }
    }
    Ok(cfg)
}

/// section -> key -> string values. Arrays may span lines; values must
/// be double-quoted strings (no escapes — these are repo paths/idents).
fn parse_sections(text: &str) -> Result<BTreeMap<String, BTreeMap<String, Vec<String>>>> {
    let mut out: BTreeMap<String, BTreeMap<String, Vec<String>>> = BTreeMap::new();
    let mut section = String::new();
    let mut lines = text.lines().enumerate();
    while let Some((ln, line)) = lines.next() {
        let line = strip_comment(line).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected `key = ...`, got `{line}`", ln + 1);
        };
        let key = key.trim().to_string();
        let mut value = value.trim().to_string();
        if value.starts_with('[') {
            // accumulate a possibly multi-line array until the closing ]
            while !value.contains(']') {
                let Some((_, next)) = lines.next() else {
                    bail!("line {}: unterminated array for `{key}`", ln + 1);
                };
                value.push(' ');
                value.push_str(strip_comment(next).trim());
            }
        }
        let values = quoted_strings(&value);
        if section.is_empty() {
            bail!("line {}: `{key}` outside any [section]", ln + 1);
        }
        out.entry(section.clone()).or_default().insert(key, values);
    }
    Ok(out)
}

/// Strip a `#` comment, ignoring `#` inside double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Every "..."-delimited string in `s`, in order.
fn quoted_strings(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = s;
    while let Some(start) = rest.find('"') {
        let tail = &rest[start + 1..];
        let Some(end) = tail.find('"') else { break };
        out.push(tail[..end].to_string());
        rest = &tail[end + 1..];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_arrays_and_comments() {
        let cfg = parse(
            "# header\n[rules.unsafe-needs-safety]\nallow_files = [\n    \"src/a.rs\", # why\n    \
             \"src/b.rs\",\n]\n\n[rules.serving-no-panic]\nfiles = [\"src/c.rs\"]\nallow_fns = []\n",
        )
        .unwrap();
        assert_eq!(cfg.unsafe_allow_files, vec!["src/a.rs", "src/b.rs"]);
        assert_eq!(cfg.panic_files, vec!["src/c.rs"]);
        assert!(cfg.panic_allow_fns.is_empty());
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(parse("[rules.unsafe-needs-safety]\nbogus = [\"x\"]\n").is_err());
    }

    #[test]
    fn checked_in_config_parses() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("lint.toml");
        let cfg = load(&path).unwrap();
        assert!(!cfg.unsafe_allow_files.is_empty());
        assert!(!cfg.fma_allow_fns.is_empty());
        assert!(!cfg.hot_fns.is_empty());
        assert!(!cfg.hot_files.is_empty());
        assert!(!cfg.spawn_allow_files.is_empty());
        assert!(!cfg.panic_files.is_empty());
        assert!(!cfg.panic_allow_fns.is_empty());
    }
}
