//! bench-diff — compare two benchmark JSON snapshots (the `--json`
//! artifacts the bench binaries write, e.g. `BENCH_fig10.json` /
//! `BENCH_table8.json`) and render a markdown delta table.
//!
//! `cargo run -p xtask -- bench-diff <old.json> <new.json>` prints one
//! row per numeric metric with the % change, classifies each metric's
//! good direction from its key (throughput-like keys are
//! higher-is-better; seconds/latency/allocation/bytes keys are
//! lower-is-better; workload/config keys are context and only checked
//! for equality), and exits non-zero when any metric moved more than
//! 20% in the bad direction — CI downloads the previous run's artifact
//! and posts the table to the step summary.
//!
//! Snapshots are nested objects of arrays of objects; metrics are
//! addressed by a flattened dotted path. Array elements are labeled by
//! their own identifying string members (`kernel`, `method`,
//! `schedule`, …) plus id-like numeric members (`pool`, `sessions`),
//! falling back to the element index — every bench emits its arrays in
//! a deterministic order, so paths are stable across runs.

use std::path::Path;

use anyhow::{Context, Result};

use sparge::util::json::Json;

/// Relative change (in the bad direction) that counts as a regression.
const GATE: f64 = 0.20;

/// Numeric members that identify an array element or describe the
/// workload/machine rather than measure it: never gated, folded into
/// labels where possible, flagged only when they change.
const CONTEXT_KEYS: &[&str] = &[
    "pool", "threads", "scale", "sessions", "frames", "frame_bytes", "d", "seed", "prefill",
    "decode", "n", "heads", "repeats",
];

/// Key fragments marking a lower-is-better metric (latency, memory,
/// allocation, straggler percentiles). Checked before the
/// higher-is-better list: `tok_s`/`*_rate` style names never match
/// these fragments.
const LOWER_BETTER: &[&str] = &[
    "ttft", "tpot", "wall", "tick", "alloc", "bytes", "evictions", "load_sheds", "p50", "p95",
    "p99", "latency", "_ms", "_us", "_ns", "overhead", "cow_splits",
];

/// Key fragments marking a higher-is-better metric (throughput, flop
/// rate, reuse).
const HIGHER_BETTER: &[&str] = &["tok_s", "gflops", "gops", "flops", "rate", "speedup", "hits"];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Direction {
    Lower,
    Higher,
    Context,
}

fn classify(path: &str) -> Direction {
    let leaf = path.rsplit('.').next().unwrap_or(path);
    if CONTEXT_KEYS.contains(&leaf) {
        return Direction::Context;
    }
    if LOWER_BETTER.iter().any(|f| leaf.contains(f)) {
        return Direction::Lower;
    }
    if HIGHER_BETTER.iter().any(|f| leaf.contains(f)) {
        return Direction::Higher;
    }
    // `*_s` with no other marker: a seconds measurement.
    if leaf.ends_with("_s") {
        return Direction::Lower;
    }
    Direction::Context
}

/// One compared metric.
#[derive(Clone, Debug)]
pub struct Delta {
    pub path: String,
    pub old: Option<f64>,
    pub new: Option<f64>,
    /// Signed relative change `new/old - 1`; `None` when either side is
    /// missing or `old == 0`.
    pub pct: Option<f64>,
    pub regression: bool,
}

/// Label for an array element: identifying string members plus id-like
/// numeric members, else the element index.
fn element_label(v: &Json, index: usize) -> String {
    let mut parts: Vec<String> = Vec::new();
    if let Json::Obj(pairs) = v {
        for (k, val) in pairs {
            match val {
                Json::Str(s) => parts.push(s.clone()),
                Json::Num(x) if CONTEXT_KEYS.contains(&k.as_str()) => {
                    parts.push(format!("{k}={x}"));
                }
                _ => {}
            }
        }
    }
    if parts.is_empty() {
        format!("{index}")
    } else {
        parts.join("/").replace('.', "_")
    }
}

/// Flatten every numeric leaf into `(dotted.path, value)`.
fn flatten(prefix: &str, v: &Json, out: &mut Vec<(String, f64)>) {
    match v {
        Json::Num(x) => out.push((prefix.to_string(), *x)),
        Json::Obj(pairs) => {
            for (k, val) in pairs {
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(&p, val, out);
            }
        }
        Json::Arr(items) => {
            for (i, it) in items.iter().enumerate() {
                let p = format!("{prefix}.{}", element_label(it, i));
                flatten(&p, it, out);
            }
        }
        _ => {}
    }
}

/// Compare two parsed snapshots: every metric present on either side,
/// old-side order first, then new-only metrics.
pub fn diff(old: &Json, new: &Json) -> Vec<Delta> {
    let mut a = Vec::new();
    let mut b = Vec::new();
    flatten("", old, &mut a);
    flatten("", new, &mut b);
    let mut out = Vec::new();
    for (path, ov) in &a {
        let nv = b.iter().find(|(p, _)| p == path).map(|(_, v)| *v);
        out.push(compare(path, Some(*ov), nv));
    }
    for (path, nv) in &b {
        if !a.iter().any(|(p, _)| p == path) {
            out.push(compare(path, None, Some(*nv)));
        }
    }
    out
}

fn compare(path: &str, old: Option<f64>, new: Option<f64>) -> Delta {
    let pct = match (old, new) {
        (Some(o), Some(n)) if o != 0.0 => Some(n / o - 1.0),
        _ => None,
    };
    let regression = match (classify(path), pct) {
        (Direction::Lower, Some(p)) => p > GATE,
        (Direction::Higher, Some(p)) => p < -GATE,
        _ => false,
    };
    Delta { path: path.to_string(), old, new, pct, regression }
}

fn fmt_val(v: Option<f64>) -> String {
    match v {
        None => "—".to_string(),
        Some(x) if x == 0.0 => "0".to_string(),
        Some(x) if x.abs() >= 1000.0 => format!("{x:.0}"),
        Some(x) if x.abs() >= 1.0 => format!("{x:.2}"),
        Some(x) => format!("{x:.4}"),
    }
}

/// Render the markdown delta table (CI posts this to the step summary).
pub fn render(title: &str, deltas: &[Delta]) -> String {
    let mut s = format!("### bench-diff: {title}\n\n");
    s.push_str("| metric | old | new | Δ | status |\n|---|---:|---:|---:|---|\n");
    for d in deltas {
        let pct = match d.pct {
            Some(p) => format!("{:+.1}%", p * 100.0),
            None => "—".to_string(),
        };
        let status = if d.regression {
            "**regression**"
        } else if d.old.is_none() {
            "new"
        } else if d.new.is_none() {
            "removed"
        } else {
            match classify(&d.path) {
                Direction::Context => {
                    if d.old == d.new {
                        "context"
                    } else {
                        "context changed"
                    }
                }
                _ => "ok",
            }
        };
        s.push_str(&format!(
            "| `{}` | {} | {} | {} | {} |\n",
            d.path,
            fmt_val(d.old),
            fmt_val(d.new),
            pct,
            status
        ));
    }
    let n = deltas.iter().filter(|d| d.regression).count();
    if n > 0 {
        s.push_str(&format!("\n**{n} metric(s) regressed more than {:.0}%.**\n", GATE * 100.0));
    } else {
        s.push_str(&format!("\nNo metric regressed more than {:.0}%.\n", GATE * 100.0));
    }
    s
}

/// CLI entry: load both snapshots, print the table, return the
/// regression count (the caller turns >0 into a failing exit code).
pub fn run_cli(old_path: &str, new_path: &str) -> Result<usize> {
    let load = |p: &str| -> Result<Json> {
        let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e}"))
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    let title = Path::new(new_path)
        .file_name()
        .map(|f| f.to_string_lossy().into_owned())
        .unwrap_or_else(|| new_path.to_string());
    let deltas = diff(&old, &new);
    print!("{}", render(&title, &deltas));
    Ok(deltas.iter().filter(|d| d.regression).count())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn flattens_with_element_labels() {
        let doc = j(r#"{"bench":"t8","threads":4,"decode_phase":[
            {"pool":1,"tok_s":100.0},{"pool":2,"tok_s":190.0}]}"#);
        let mut out = Vec::new();
        flatten("", &doc, &mut out);
        let paths: Vec<&str> = out.iter().map(|(p, _)| p.as_str()).collect();
        assert!(paths.contains(&"threads"));
        assert!(paths.contains(&"decode_phase.pool=1.tok_s"), "{paths:?}");
        assert!(paths.contains(&"decode_phase.pool=2.pool"));
    }

    #[test]
    fn string_members_label_elements() {
        let doc = j(r#"{"sweep":[{"method":"sparge","target":"cos 0.95","gflops":9.0}]}"#);
        let mut out = Vec::new();
        flatten("", &doc, &mut out);
        assert_eq!(out[0].0, "sweep.sparge/cos 0_95.gflops");
    }

    #[test]
    fn direction_classification() {
        assert_eq!(classify("decode_phase.pool=2.tok_s"), Direction::Higher);
        assert_eq!(classify("mixed.sequential.ttft_p95_s"), Direction::Lower);
        assert_eq!(classify("mixed.sequential.wall_s"), Direction::Lower);
        assert_eq!(classify("paged.sessions=8.peak_bytes"), Direction::Lower);
        assert_eq!(classify("paged.sessions=8.prefix_hits"), Direction::Higher);
        assert_eq!(classify("threads"), Direction::Context);
        assert_eq!(classify("paged.sessions=8.frame_bytes"), Direction::Context);
    }

    #[test]
    fn gates_regressions_in_the_bad_direction_only() {
        let old = j(r#"{"a":{"tok_s":100.0,"ttft_mean_s":0.10},"threads":4}"#);
        // throughput -30% (regression), latency -50% (improvement),
        // context change (not gated)
        let new = j(r#"{"a":{"tok_s":70.0,"ttft_mean_s":0.05},"threads":8}"#);
        let d = diff(&old, &new);
        let find = |p: &str| d.iter().find(|x| x.path == p).unwrap();
        assert!(find("a.tok_s").regression);
        assert!(!find("a.ttft_mean_s").regression);
        assert!(!find("threads").regression);
        assert_eq!(d.iter().filter(|x| x.regression).count(), 1);
    }

    #[test]
    fn improvement_and_small_moves_pass() {
        let old = j(r#"{"a":{"tok_s":100.0,"wall_s":2.0}}"#);
        let new = j(r#"{"a":{"tok_s":85.0,"wall_s":2.3}}"#);
        // -15% throughput and +15% wall: both inside the 20% gate
        assert_eq!(diff(&old, &new).iter().filter(|x| x.regression).count(), 0);
        let faster = j(r#"{"a":{"tok_s":300.0,"wall_s":0.5}}"#);
        assert_eq!(diff(&old, &faster).iter().filter(|x| x.regression).count(), 0);
    }

    #[test]
    fn missing_and_new_metrics_do_not_gate() {
        let old = j(r#"{"a":{"tok_s":100.0}}"#);
        let new = j(r#"{"b":{"tok_s":10.0}}"#);
        let d = diff(&old, &new);
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| !x.regression));
        let md = render("t", &d);
        assert!(md.contains("removed"));
        assert!(md.contains("new"));
    }

    #[test]
    fn render_flags_regressions() {
        let old = j(r#"{"a":{"tok_s":100.0}}"#);
        let new = j(r#"{"a":{"tok_s":10.0}}"#);
        let md = render("BENCH_table8.json", &diff(&old, &new));
        assert!(md.contains("**regression**"), "{md}");
        assert!(md.contains("1 metric(s) regressed"), "{md}");
        assert!(md.contains("-90.0%"), "{md}");
    }

    #[test]
    fn zero_baseline_is_not_gated() {
        let old = j(r#"{"a":{"allocs_per_token":0.0}}"#);
        let new = j(r#"{"a":{"allocs_per_token":3.0}}"#);
        let d = diff(&old, &new);
        assert_eq!(d[0].pct, None);
        assert!(!d[0].regression);
    }
}
