//! Repo task runner: `cargo run -p xtask -- lint` (repo-contract static
//! analysis; see src/lint.rs and lint.toml) and `cargo run -p xtask --
//! bench-diff <old.json> <new.json>` (benchmark snapshot comparison
//! with a >20% regression gate; see src/bench_diff.rs). CONTRIBUTING.md
//! has the full contract map.

use std::process::ExitCode;

mod bench_diff;
mod config;
mod lint;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    match args.get(1).map(String::as_str) {
        Some("lint") => match lint::run_cli() {
            Ok(0) => {
                eprintln!("sparge-lint: tree is clean");
                ExitCode::SUCCESS
            }
            Ok(n) => {
                eprintln!("sparge-lint: {n} finding(s)");
                ExitCode::FAILURE
            }
            Err(e) => {
                eprintln!("sparge-lint: error: {e:#}");
                ExitCode::FAILURE
            }
        },
        Some("bench-diff") => match (args.get(2), args.get(3)) {
            (Some(old), Some(new)) => match bench_diff::run_cli(old, new) {
                Ok(0) => ExitCode::SUCCESS,
                Ok(n) => {
                    eprintln!("bench-diff: {n} regression(s) beyond the gate");
                    ExitCode::FAILURE
                }
                Err(e) => {
                    eprintln!("bench-diff: error: {e:#}");
                    ExitCode::FAILURE
                }
            },
            _ => {
                eprintln!("usage: cargo run -p xtask -- bench-diff <old.json> <new.json>");
                ExitCode::FAILURE
            }
        },
        _ => {
            eprintln!(
                "usage: cargo run -p xtask -- lint\n       cargo run -p xtask -- bench-diff <old.json> <new.json>"
            );
            ExitCode::FAILURE
        }
    }
}
