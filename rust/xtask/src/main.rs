//! Repo task runner. The only task so far is the repo-contract static
//! analysis: `cargo run -p xtask -- lint` (see src/lint.rs and
//! lint.toml; CONTRIBUTING.md has the full contract map).

use std::process::ExitCode;

mod config;
mod lint;

fn main() -> ExitCode {
    match std::env::args().nth(1).as_deref() {
        Some("lint") => match lint::run_cli() {
            Ok(0) => {
                eprintln!("sparge-lint: tree is clean");
                ExitCode::SUCCESS
            }
            Ok(n) => {
                eprintln!("sparge-lint: {n} finding(s)");
                ExitCode::FAILURE
            }
            Err(e) => {
                eprintln!("sparge-lint: error: {e:#}");
                ExitCode::FAILURE
            }
        },
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            ExitCode::FAILURE
        }
    }
}
