//! sparge-lint — a syn AST walk over `rust/src` + `rust/tests` that
//! enforces the repo's written correctness contracts as machine-checked
//! rules. Rules and their allowlists live in `xtask/lint.toml`; the
//! contract → rule → runtime-suite map lives in CONTRIBUTING.md.
//!
//! Comments are invisible to syn, so `// SAFETY:` and
//! `// sparge-lint: allow(<rule>)` markers are resolved against the raw
//! source: a marker counts if it appears on the finding's line (as a
//! trailing comment) or anywhere in the contiguous comment/attribute
//! block directly above it.

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};
use proc_macro2::Span;
use syn::visit::Visit;

use crate::config::{self, Config};

pub const RULE_UNSAFE: &str = "unsafe-needs-safety";
pub const RULE_FMA: &str = "fixed-order-no-fma";
pub const RULE_ALLOC: &str = "hot-path-no-alloc";
pub const RULE_SPAWN: &str = "no-raw-thread-spawn";
pub const RULE_PANIC: &str = "serving-no-panic";

/// One diagnostic. Ord is (file, line, col, ...) so a sorted report
/// reads top-to-bottom per file.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub col: usize,
    pub rule: String,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}: [{}] {}", self.file, self.line, self.col, self.rule, self.msg)
    }
}

/// Raw source lines (1-indexed) for comment-marker resolution.
struct SourceMap {
    lines: Vec<String>,
}

impl SourceMap {
    fn new(source: &str) -> Self {
        Self { lines: source.lines().map(str::to_string).collect() }
    }

    fn line(&self, n: usize) -> &str {
        // 1-indexed (proc-macro2 line numbers); out of range reads as "".
        self.lines.get(n.wrapping_sub(1)).map_or("", String::as_str)
    }

    /// True if `pred` matches the finding's own line or any line of the
    /// contiguous comment/attribute block directly above it.
    fn marker_above(&self, line: usize, pred: impl Fn(&str) -> bool) -> bool {
        if pred(self.line(line)) {
            return true;
        }
        let mut l = line;
        while l > 1 {
            l -= 1;
            let t = self.line(l).trim_start();
            if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!") {
                if pred(t) {
                    return true;
                }
            } else {
                return false;
            }
        }
        false
    }

    fn safety_above(&self, line: usize) -> bool {
        self.marker_above(line, |l| l.contains("SAFETY") || l.contains("# Safety"))
    }
}

struct FnCtx {
    /// Bare fn name, e.g. `decode_into`.
    plain: String,
    /// `Type::name` inside an impl block, else same as `plain`.
    qualified: String,
}

struct Linter<'c> {
    cfg: &'c Config,
    /// Path relative to rust/, forward slashes (matches lint.toml).
    file: String,
    in_tests_dir: bool,
    src: SourceMap,
    /// > 0 inside `#[cfg(test)]` mods / `#[test]` fns.
    test_depth: usize,
    fn_stack: Vec<FnCtx>,
    impl_stack: Vec<String>,
    findings: Vec<Finding>,
}

impl Linter<'_> {
    fn in_test(&self) -> bool {
        self.in_tests_dir || self.test_depth > 0
    }

    /// Any enclosing fn matching `list` by plain or qualified name.
    fn fn_matches(&self, list: &[String]) -> bool {
        self.fn_stack
            .iter()
            .any(|f| list.iter().any(|e| e == &f.plain || e == &f.qualified))
    }

    fn is_hot(&self) -> bool {
        self.cfg.hot_files.iter().any(|f| f == &self.file) || self.fn_matches(&self.cfg.hot_fns)
    }

    /// fixed-order-no-fma allow entries are `file.rs::fn`.
    fn fma_allowed(&self) -> bool {
        self.fn_stack.iter().any(|f| {
            let key = format!("{}::{}", self.file, f.plain);
            self.cfg.fma_allow_fns.iter().any(|e| e == &key)
        })
    }

    fn suppressed(&self, rule: &str, line: usize) -> bool {
        let marker = format!("sparge-lint: allow({rule})");
        self.src.marker_above(line, |l| l.contains(marker.as_str()))
    }

    fn emit(&mut self, rule: &str, span: Span, msg: String) {
        let start = span.start();
        if self.suppressed(rule, start.line) {
            return;
        }
        self.findings.push(Finding {
            file: self.file.clone(),
            line: start.line,
            col: start.column + 1,
            rule: rule.to_string(),
            msg,
        });
    }

    fn check_unsafe(&mut self, span: Span, what: &str) {
        let line = span.start().line;
        if !self.cfg.unsafe_allow_files.iter().any(|f| f == &self.file) {
            self.emit(
                RULE_UNSAFE,
                span,
                format!("`unsafe` {what} in a file outside the unsafe allowlist (xtask/lint.toml)"),
            );
        } else if !self.src.safety_above(line) {
            self.emit(
                RULE_UNSAFE,
                span,
                format!("`unsafe` {what} without a `// SAFETY:` comment in the block above"),
            );
        }
    }

    fn check_fma(&mut self, span: Span, what: &str) {
        if self.in_test() || self.fma_allowed() {
            return;
        }
        self.emit(
            RULE_FMA,
            span,
            format!(
                "fused `{what}` outside the oracle-tier matmul_nn_acc breaks the fixed-order \
                 bitwise contract"
            ),
        );
    }

    fn check_alloc(&mut self, span: Span, what: &str) {
        if self.in_test() || !self.is_hot() {
            return;
        }
        self.emit(
            RULE_ALLOC,
            span,
            format!("allocating construct `{what}` in a declared hot path (see tests/alloc_regression.rs)"),
        );
    }

    fn check_spawn(&mut self, span: Span, what: &str) {
        if self.in_test() || self.cfg.spawn_allow_files.iter().any(|f| f == &self.file) {
            return;
        }
        self.emit(
            RULE_SPAWN,
            span,
            format!("raw `{what}` outside util/threadpool.rs — route parallel work through the Exec seam"),
        );
    }

    fn check_panic(&mut self, span: Span, what: &str) {
        if self.in_test()
            || !self.cfg.panic_files.iter().any(|f| f == &self.file)
            || self.fn_matches(&self.cfg.panic_allow_fns)
        {
            return;
        }
        self.emit(
            RULE_PANIC,
            span,
            format!("`{what}` in the serving loop — degrade and report instead of dying"),
        );
    }

    fn push_fn(&mut self, name: String) {
        let qualified = match self.impl_stack.last() {
            Some(ty) => format!("{ty}::{name}"),
            None => name.clone(),
        };
        self.fn_stack.push(FnCtx { plain: name, qualified });
    }
}

fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| match &a.meta {
        syn::Meta::List(ml) if ml.path.is_ident("cfg") => ml.tokens.to_string().contains("test"),
        _ => false,
    })
}

fn is_test_fn(attrs: &[syn::Attribute]) -> bool {
    is_cfg_test(attrs)
        || attrs.iter().any(|a| {
            a.path().segments.last().is_some_and(|s| s.ident == "test")
        })
}

fn self_type_name(ty: &syn::Type) -> String {
    match ty {
        syn::Type::Path(p) => p
            .path
            .segments
            .last()
            .map(|s| s.ident.to_string())
            .unwrap_or_default(),
        syn::Type::Reference(r) => self_type_name(&r.elem),
        _ => String::new(),
    }
}

impl<'ast> Visit<'ast> for Linter<'_> {
    fn visit_item_mod(&mut self, m: &'ast syn::ItemMod) {
        let test = is_cfg_test(&m.attrs);
        if test {
            self.test_depth += 1;
        }
        syn::visit::visit_item_mod(self, m);
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_item_fn(&mut self, f: &'ast syn::ItemFn) {
        let test = is_test_fn(&f.attrs);
        if test {
            self.test_depth += 1;
        }
        if let Some(u) = f.sig.unsafety {
            self.check_unsafe(u.span, "fn");
        }
        self.push_fn(f.sig.ident.to_string());
        syn::visit::visit_item_fn(self, f);
        self.fn_stack.pop();
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_impl_item_fn(&mut self, f: &'ast syn::ImplItemFn) {
        let test = is_test_fn(&f.attrs);
        if test {
            self.test_depth += 1;
        }
        if let Some(u) = f.sig.unsafety {
            self.check_unsafe(u.span, "fn");
        }
        self.push_fn(f.sig.ident.to_string());
        syn::visit::visit_impl_item_fn(self, f);
        self.fn_stack.pop();
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_item_impl(&mut self, i: &'ast syn::ItemImpl) {
        let test = is_cfg_test(&i.attrs);
        if test {
            self.test_depth += 1;
        }
        if let Some(u) = i.unsafety {
            self.check_unsafe(u.span, "impl");
        }
        self.impl_stack.push(self_type_name(&i.self_ty));
        syn::visit::visit_item_impl(self, i);
        self.impl_stack.pop();
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_expr_unsafe(&mut self, e: &'ast syn::ExprUnsafe) {
        self.check_unsafe(e.unsafe_token.span, "block");
        syn::visit::visit_expr_unsafe(self, e);
    }

    fn visit_expr_method_call(&mut self, m: &'ast syn::ExprMethodCall) {
        let name = m.method.to_string();
        let span = m.method.span();
        match name.as_str() {
            "mul_add" => self.check_fma(span, "mul_add"),
            "unwrap" | "expect" => self.check_panic(span, &format!(".{name}()")),
            "to_vec" | "to_owned" | "to_string" | "collect" | "clone" => {
                self.check_alloc(span, &format!(".{name}()"));
            }
            _ => {}
        }
        syn::visit::visit_expr_method_call(self, m);
    }

    fn visit_path(&mut self, p: &'ast syn::Path) {
        let segs: Vec<(String, Span)> =
            p.segments.iter().map(|s| (s.ident.to_string(), s.ident.span())).collect();
        for (name, span) in &segs {
            if name.contains("fmadd") {
                self.check_fma(*span, name);
            }
        }
        for w in segs.windows(2) {
            let span = w[1].1;
            match (w[0].0.as_str(), w[1].0.as_str()) {
                ("Vec", "new")
                | ("Vec", "with_capacity")
                | ("Box", "new")
                | ("String", "new")
                | ("String", "from")
                | ("String", "with_capacity")
                | ("HashMap", "new")
                | ("BTreeMap", "new") => {
                    self.check_alloc(span, &format!("{}::{}", w[0].0, w[1].0));
                }
                ("thread", "spawn") | ("thread", "scope") | ("thread", "Builder") => {
                    self.check_spawn(span, &format!("thread::{}", w[1].0));
                }
                _ => {}
            }
        }
        syn::visit::visit_path(self, p);
    }

    fn visit_macro(&mut self, mac: &'ast syn::Macro) {
        if let Some(seg) = mac.path.segments.last() {
            let name = seg.ident.to_string();
            let span = seg.ident.span();
            match name.as_str() {
                "vec" | "format" => self.check_alloc(span, &format!("{name}!")),
                "panic" | "unreachable" | "todo" | "unimplemented" => {
                    self.check_panic(span, &format!("{name}!"));
                }
                _ => {}
            }
        }
        syn::visit::visit_macro(self, mac);
    }
}

/// Lint one file's source. `rel_path` is relative to rust/ with forward
/// slashes — it is what lint.toml allowlists match against.
pub fn lint_source(cfg: &Config, rel_path: &str, source: &str) -> Result<Vec<Finding>> {
    let ast = syn::parse_file(source).with_context(|| format!("parsing {rel_path}"))?;
    let mut linter = Linter {
        cfg,
        file: rel_path.to_string(),
        in_tests_dir: rel_path.starts_with("tests/"),
        src: SourceMap::new(source),
        test_depth: 0,
        fn_stack: Vec::new(),
        impl_stack: Vec::new(),
        findings: Vec::new(),
    };
    linter.visit_file(&ast);
    let mut findings = linter.findings;
    findings.sort();
    Ok(findings)
}

/// Lint every .rs file under `root`/src and `root`/tests.
pub fn lint_tree(cfg: &Config, root: &Path) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    for top in ["src", "tests"] {
        collect_rs(&root.join(top), &mut files)?;
    }
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let source =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        findings.extend(lint_source(cfg, &rel, &source)?);
    }
    findings.sort();
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// CLI entry: lint the checked-out tree against xtask/lint.toml, print
/// `file:line:col: [rule] msg` diagnostics, return the finding count.
pub fn run_cli() -> Result<usize> {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = config::load(&manifest.join("lint.toml"))?;
    let root = manifest.parent().context("xtask has no parent dir")?;
    let findings = lint_tree(&cfg, root)?;
    for f in &findings {
        eprintln!("{f}");
    }
    Ok(findings.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_cfg() -> Config {
        config::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("lint.toml")).unwrap()
    }

    fn lint_str(rel: &str, src: &str) -> Vec<Finding> {
        lint_source(&repo_cfg(), rel, src).unwrap()
    }

    fn rules(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule.as_str()).collect()
    }

    #[test]
    fn unsafe_outside_allowlist_fires() {
        let f = lint_str(
            "src/attention/block_mask.rs",
            "pub fn read(p: *const f32) -> f32 {\n    // SAFETY: p is valid.\n    unsafe { *p }\n}\n",
        );
        assert_eq!(rules(&f), vec![RULE_UNSAFE]);
        assert!(f[0].msg.contains("allowlist"), "{}", f[0]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn unsafe_without_safety_fires_and_comment_quiets() {
        let bare = "pub fn read(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n";
        let f = lint_str("src/util/alloc.rs", bare);
        assert_eq!(rules(&f), vec![RULE_UNSAFE]);
        assert!(f[0].msg.contains("SAFETY"), "{}", f[0]);

        let documented =
            "pub fn read(p: *const f32) -> f32 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        assert!(lint_str("src/util/alloc.rs", documented).is_empty());
    }

    #[test]
    fn unsafe_fn_and_impl_need_safety() {
        let f = lint_str(
            "src/util/threadpool.rs",
            "pub struct P(*mut u8);\nunsafe impl Send for P {}\npub unsafe fn touch(p: P) {}\n",
        );
        assert_eq!(rules(&f), vec![RULE_UNSAFE, RULE_UNSAFE]);

        let documented = "pub struct P(*mut u8);\n// SAFETY: P is only dereferenced by its owner.\nunsafe impl Send for P {}\n/// # Safety\n/// Caller must own `p`.\npub unsafe fn touch(p: P) {}\n";
        assert!(lint_str("src/util/threadpool.rs", documented).is_empty());
    }

    #[test]
    fn mul_add_fires_outside_oracle_tier_only() {
        let body = "pub fn dot_tail(a: f32, b: f32, c: f32) -> f32 {\n    a.mul_add(b, c)\n}\n";
        let f = lint_str("src/tensor/microkernel/portable.rs", body);
        assert_eq!(rules(&f), vec![RULE_FMA]);

        let oracle = "pub fn matmul_nn_acc(a: f32, b: f32, c: f32) -> f32 {\n    a.mul_add(b, c)\n}\n";
        assert!(lint_str("src/tensor/microkernel/portable.rs", oracle).is_empty());
        // The allow entry is file-qualified: the same fn name elsewhere still fires.
        assert_eq!(rules(&lint_str("src/attention/predictor.rs", oracle)), vec![RULE_FMA]);
    }

    #[test]
    fn fmadd_intrinsic_path_fires() {
        let f = lint_str(
            "src/tensor/microkernel/avx2.rs",
            "pub fn qk(a: f32) -> f32 {\n    crate::intrin::_mm256_fmadd_ps(a, a, a)\n}\n",
        );
        assert_eq!(rules(&f), vec![RULE_FMA]);
    }

    #[test]
    fn hot_fn_alloc_fires_and_suppression_quiets() {
        let body = "pub fn reduce_span(n: usize) -> usize {\n    let v: Vec<f32> = Vec::new();\n    v.len() + n\n}\n";
        let f = lint_str("src/attention/pipeline.rs", body);
        assert_eq!(rules(&f), vec![RULE_ALLOC]);
        assert_eq!(f[0].line, 2);

        let suppressed = "pub fn reduce_span(n: usize) -> usize {\n    // sparge-lint: allow(hot-path-no-alloc) — fixture\n    let v: Vec<f32> = Vec::new();\n    v.len() + n\n}\n";
        assert!(lint_str("src/attention/pipeline.rs", suppressed).is_empty());

        // A fn that is not declared hot, in a non-hot file: quiet.
        let cold = "pub fn setup(n: usize) -> Vec<f32> {\n    let mut v = Vec::new();\n    v.resize(n, 0.0);\n    v\n}\n";
        assert!(lint_str("src/attention/pipeline.rs", cold).is_empty());
    }

    #[test]
    fn hot_file_macros_and_methods_fire() {
        let f = lint_str(
            "src/tensor/microkernel/portable.rs",
            "pub fn pad(xs: &[f32]) -> usize {\n    let v = vec![0.0f32; 8];\n    let w = xs.to_vec();\n    v.len() + w.len()\n}\n",
        );
        assert_eq!(rules(&f), vec![RULE_ALLOC, RULE_ALLOC]);
    }

    #[test]
    fn qualified_hot_fn_matches_impl_method() {
        let f = lint_str(
            "src/coordinator/session_manager.rs",
            "pub struct SessionManager;\nimpl SessionManager {\n    pub fn tick(&mut self) {\n        let done: Vec<usize> = Vec::new();\n        drop(done);\n    }\n}\n",
        );
        assert_eq!(rules(&f), vec![RULE_ALLOC]);
    }

    #[test]
    fn raw_spawn_fires_outside_threadpool() {
        let body = "pub fn fan_out() {\n    std::thread::spawn(|| {});\n}\n";
        assert_eq!(rules(&lint_str("src/attention/pipeline.rs", body)), vec![RULE_SPAWN]);
        assert!(lint_str("src/util/threadpool.rs", body).is_empty());
        assert!(lint_str("src/coordinator/engine.rs", body).is_empty());

        let scoped = "pub fn fan_out() {\n    std::thread::scope(|s| { let _ = s; });\n}\n";
        assert_eq!(rules(&lint_str("src/attention/engine.rs", scoped)), vec![RULE_SPAWN]);
    }

    #[test]
    fn serving_panic_fires_and_allow_fn_quiets() {
        let body = "pub fn route(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        assert_eq!(rules(&lint_str("src/coordinator/server.rs", body)), vec![RULE_PANIC]);
        // Same construct outside the serving files: quiet.
        assert!(lint_str("src/attention/pipeline.rs", body).is_empty());

        let macros = "pub fn route(x: u32) -> u32 {\n    if x > 3 { panic!(\"boom\") } else { x }\n}\n";
        assert_eq!(rules(&lint_str("src/coordinator/scheduler.rs", macros)), vec![RULE_PANIC]);

        let startup = "pub struct Coordinator;\nimpl Coordinator {\n    pub fn start_with(x: Option<u32>) -> u32 {\n        x.expect(\"fail-fast startup\")\n    }\n}\n";
        assert!(lint_str("src/coordinator/scheduler.rs", startup).is_empty());
    }

    #[test]
    fn test_code_is_exempt_from_alloc_spawn_panic() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let v = vec![1];\n        std::thread::spawn(move || v.len()).join().unwrap();\n    }\n}\n";
        // A hot file: rule 3 and 4 would both fire were this not test code.
        assert!(lint_str("src/tensor/microkernel/portable.rs", src).is_empty());
        // A serving file: rule 5 would fire on the unwrap.
        assert!(lint_str("src/coordinator/server.rs", src).is_empty());
        // tests/ directory files are exempt wholesale for rules 3/4/5.
        let plain = "pub fn helper(x: Option<u32>) -> u32 {\n    let v: Vec<u32> = Vec::new();\n    std::thread::spawn(|| {});\n    x.unwrap() + v.len() as u32\n}\n";
        assert!(lint_str("tests/workspace_parity.rs", plain).is_empty());
    }

    #[test]
    fn checked_in_tree_is_clean() {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        let cfg = config::load(&manifest.join("lint.toml")).unwrap();
        let findings = lint_tree(&cfg, manifest.parent().unwrap()).unwrap();
        assert!(
            findings.is_empty(),
            "lint findings on the checked-in tree:\n{}",
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
