use sparge::tensor::{matmul, Tensor};
use sparge::util::rng::Pcg;
use std::time::Instant;
fn main() {
    let mut rng = Pcg::seeded(1);
    let (m, n, k) = (1024, 1024, 64);
    let a = Tensor::randn(&[m, k], &mut rng);
    let b = Tensor::randn(&[n, k], &mut rng);
    let mut c = vec![0f32; m * n];
    matmul::matmul_nt_into(a.data(), b.data(), &mut c, m, n, k);
    let t0 = Instant::now();
    let reps = 20;
    for _ in 0..reps { matmul::matmul_nt_into(a.data(), b.data(), &mut c, m, n, k); }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    let gflops = 2.0 * (m * n * k) as f64 / dt / 1e9;
    println!("matmul_nt: {:.2} GFLOP/s ({:.1} ms)", gflops, dt * 1e3);
    // nn kernel
    let b2 = Tensor::randn(&[k, n], &mut rng);
    let t0 = Instant::now();
    for _ in 0..reps { matmul::matmul_nn_acc(a.data(), b2.data(), &mut c, m, n, k, true); }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!("matmul_nn: {:.2} GFLOP/s", 2.0 * (m * n * k) as f64 / dt / 1e9);
    // i8 kernel
    let ai: Vec<i8> = (0..m*k).map(|i| (i % 200) as i8).collect();
    let bi: Vec<i8> = (0..n*k).map(|i| (i % 180) as i8).collect();
    let mut ci = vec![0i32; m*n];
    let t0 = Instant::now();
    for _ in 0..reps { matmul::matmul_nt_i8(&ai, &bi, &mut ci, m, n, k); }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!("matmul_i8: {:.2} GOPS", 2.0 * (m * n * k) as f64 / dt / 1e9);
}
