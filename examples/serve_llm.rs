//! End-to-end driver (DESIGN.md §5 / EXPERIMENTS.md §E2E): proves all
//! three layers compose on a real workload.
//!
//! 1. **Train** the byte-level tiny LM *through the Rust runtime* — the
//!    `lm_train_step` HLO artifact (JAX-authored fwd+bwd+Adam) executed
//!    step by step from Rust on synthetic text; logs the loss curve.
//! 2. **Serve** the trained model through the coordinator (queue → dynamic
//!    batcher → engine): batched generation requests in dense and sparge
//!    attention modes, reporting latency/throughput.
//! 3. **Evaluate**: held-out perplexity and a Needle-in-a-Haystack
//!    retrieval check (the paper's Table 1 text row), dense vs sparge.
//!
//!     cargo run --release --example serve_llm -- [--steps 300] [--requests 8]
//!
//! Requires `make artifacts`.

use std::sync::Arc;

use sparge::coordinator::{AttnMode, BatchPolicy, Coordinator, EngineHandle};
use sparge::coordinator::engine::{TRAIN_B, TRAIN_T};
use sparge::runtime::Manifest;
use sparge::tensor::Tensor;
use sparge::util::cli::Args;
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, Table};
use sparge::workloads::{text, trace};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300);
    let n_requests = args.get_usize("requests", 8);
    let dir = Manifest::default_dir();

    println!("=== [1/3] train byte-LM through lm_train_step HLO ({steps} steps of {TRAIN_B}x{TRAIN_T}) ===");
    let engine = EngineHandle::spawn(&dir)?;
    let mut rng = Pcg::seeded(42);
    let corpus = text::corpus_with_kv(1 << 20, &mut rng);
    let t0 = std::time::Instant::now();
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for step in 0..steps {
        let mut batch = Vec::with_capacity(TRAIN_B * TRAIN_T);
        for _ in 0..TRAIN_B {
            let start = rng.range(0, corpus.len() - TRAIN_T - 1);
            batch.extend(corpus[start..start + TRAIN_T].iter().map(|&b| b as i32));
        }
        let loss = engine.train_step(batch)?;
        if step == 0 {
            first = loss;
        }
        last = loss;
        if step % 25 == 0 || step + 1 == steps {
            println!("  step {step:4}  loss {loss:.4}  ppl {:7.2}  ({:.0}s)", loss.exp(), t0.elapsed().as_secs_f64());
        }
    }
    println!("  loss curve: {first:.3} -> {last:.3} (ppl {:.1} -> {:.1})", first.exp(), last.exp());
    // checkpoint the trained weights for `sparge serve --weights`
    let params = engine.get_params()?;
    let ckpt = dir.join("lm_trained.spg");
    trace::save(&ckpt, &[Tensor::from_vec(&[params.len()], params)])?;
    println!("  checkpoint: {}", ckpt.display());

    println!("\n=== [2/3] serve batched generation (coordinator: queue -> batcher -> engine) ===");
    let coordinator = Arc::new(Coordinator::start(engine, BatchPolicy::default()));
    let mut serve_table = Table::new(
        "batched serving",
        &["mode", "requests", "p50 latency (ms)", "p99 latency (ms)", "tokens/s"],
    );
    for mode in [AttnMode::Dense, AttnMode::Sparge] {
        // warm-up: first request per mode pays one-time XLA compilation
        coordinator.generate(corpus[..32].to_vec(), 1, mode)?;
        // fire a burst of requests so the batcher actually batches
        let mut rxs = Vec::new();
        let mut prompt_rng = Pcg::seeded(9);
        for _ in 0..n_requests {
            let start = prompt_rng.range(0, corpus.len() - 64);
            let prompt = corpus[start..start + 48].to_vec();
            rxs.push(coordinator.submit(prompt, 8, mode)?);
        }
        let mut lats = Vec::new();
        let mut toks = 0usize;
        let mut compute = 0f64;
        for rx in rxs {
            let resp = rx.recv()?;
            lats.push(resp.latency * 1e3);
            toks += resp.output.len();
            compute += resp.compute;
        }
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        serve_table.row(&[
            mode.name().into(),
            n_requests.to_string(),
            fnum(sparge::util::stats::percentile_sorted(&lats, 0.5), 0),
            fnum(sparge::util::stats::percentile_sorted(&lats, 0.99), 0),
            fnum(toks as f64 / compute, 1),
        ]);
    }
    serve_table.print();
    println!("note: on the HLO path sparge runs *simulated* skipping (masking) plus in-graph");
    println!("prediction, so it does not beat dense wall-clock here; real skipping speedups");
    println!("are measured in the Rust engine benches (quickstart, fig10, table2).");

    println!("\n=== [3/3] evaluate: held-out perplexity + NIAH retrieval (dense vs sparge) ===");
    let engine = coordinator.engine().clone();
    let mut eval_rng = Pcg::seeded(1234);
    let heldout = text::corpus(TRAIN_T * 4, &mut eval_rng);
    let mut eval_table = Table::new(
        "quality (paper Table 1 text row, proxy scale)",
        &["mode", "ppl (held-out)", "NIAH acc", "mean gen latency (ms)"],
    );
    for mode in [AttnMode::Dense, AttnMode::Sparge] {
        // score in train-context-sized windows (the model was trained at
        // 256 tokens; longer windows would measure length extrapolation)
        let mut nll = 0.0;
        let chunks = 4;
        for c in 0..chunks {
            nll += engine.score_nll(&heldout[c * TRAIN_T..(c + 1) * TRAIN_T], mode)?;
        }
        let nll = nll / chunks as f64;
        // NIAH: 4 depths at the longest exported context
        let mut acc_sum = 0f64;
        let mut lat_sum = 0f64;
        let n_niah = 4;
        for i in 0..n_niah {
            let depth = (i as f64 + 0.5) / n_niah as f64;
            let mut nrng = Pcg::new(77, i as u64);
            // within the training context length (the 0.9M byte-LM does not
            // length-generalize; the paper's Llama evaluates at 24K-128K)
            let inst = text::niah(236, depth, &mut nrng);
            let t0 = std::time::Instant::now();
            let out = engine.generate(&inst.prompt, inst.answer.len(), mode)?;
            lat_sum += t0.elapsed().as_secs_f64();
            acc_sum += text::niah_score(&out, &inst.answer);
        }
        eval_table.row(&[
            mode.name().into(),
            fnum(nll.exp(), 3),
            fnum(acc_sum / n_niah as f64, 2),
            fnum(lat_sum / n_niah as f64 * 1e3, 0),
        ]);
    }
    eval_table.print();

    let snap = coordinator.metrics.snapshot();
    println!(
        "\ncoordinator metrics: {} requests, {} tokens, p50 {:.0}ms, p99 {:.0}ms, {} errors",
        snap.requests,
        snap.tokens_out,
        snap.latency_p50 * 1e3,
        snap.latency_p99 * 1e3,
        snap.errors
    );
    Ok(())
}
