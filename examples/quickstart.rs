//! Quickstart: run SpargeAttn on a structured workload and compare it to
//! dense FlashAttention — accuracy (relative L1), sparsity, and wall-clock
//! speedup from *real* block skipping.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts needed: this exercises the pure-Rust L3 engine.

use sparge::attention::types::AttnConfig;
use sparge::attention::AttnEngine;
use sparge::sparge::metrics::rel_l1;
use sparge::sparge::SpargeParams;
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, pct, Table};
use sparge::util::timer::time_once;
use sparge::workloads::{synthetic, SyntheticSpec};

fn main() {
    let n = 8192;
    let d = 64;
    println!("SpargeAttn quickstart — N={n}, d={d}, LM-like workload\n");

    let spec = SyntheticSpec::lm_like(n, d);
    let mut rng = Pcg::seeded(7);
    let s = synthetic::generate(&spec, &mut rng);

    let cfg = AttnConfig { bq: 128, bk: 64, causal: false, scale: None, cw: 4 };
    let (dense, t_dense) = time_once(|| AttnEngine::dense(cfg).attention(&s.q, &s.k, &s.v).out);

    let mut table = Table::new(
        "sparge vs dense (same inputs, same kernel family)",
        &["setting", "sparsity", "rel-L1", "time (ms)", "speedup"],
    );
    table.row(&[
        "dense flash".into(),
        pct(0.0),
        "0".into(),
        fnum(t_dense * 1e3, 1),
        "1.00x".into(),
    ]);

    for (label, params) in [
        ("sparge tau=0.98", SpargeParams { tau: 0.98, theta: 0.4, lambda: Some(-8.0), quant: false }),
        ("sparge tau=0.95", SpargeParams { tau: 0.95, theta: 0.4, lambda: Some(-8.0), quant: false }),
        ("sparge tau=0.90", SpargeParams { tau: 0.90, theta: 0.4, lambda: Some(-8.0), quant: false }),
        ("sparge 0.95+int8", SpargeParams { tau: 0.95, theta: 0.4, lambda: Some(-8.0), quant: true }),
    ] {
        let engine = AttnEngine::sparge(cfg, &params);
        let (res, t) = time_once(|| engine.attention(&s.q, &s.k, &s.v));
        table.row(&[
            label.into(),
            pct(res.stats.sparsity()),
            fnum(rel_l1(&res.out, &dense), 4),
            fnum(t * 1e3, 1),
            format!("{:.2}x", t_dense / t),
        ]);
    }
    table.print();

    println!("\nNotes:");
    println!("- sparsity counts skipped QK^T + PV block matmuls (paper Sec. 4.1)");
    println!("- rel-L1 = sum|O-O'|/sum|O| vs dense (paper Sec. 3.6)");
    println!("- speedup is real wall-clock from skipping, including prediction overhead");
}
