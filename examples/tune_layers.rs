//! Hyper-parameter tuning example (paper §3.6): run the two-stage
//! (τ, θ) → λ grid search for each proxy model in the Table-1 suite and
//! save the per-layer configs the coordinator consumes.
//!
//!     cargo run --release --example tune_layers -- [--scale 16] [--out-dir /tmp]
//!
//! Engine-only (no artifacts needed).

use sparge::models::{suite, Workload};
use sparge::sparge::tune::{tune_layer, CalibSample, TuneOptions};
use sparge::sparge::ModelSpargeConfig;
use sparge::util::cli::Args;
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, pct, Table};
use sparge::workloads;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = args.get_usize("scale", 32);
    let n_samples = args.get_usize("samples", 2);

    let mut table = Table::new(
        "two-stage grid search per model (paper Sec. 3.6 bounds)",
        &["model", "N", "l1/l2", "tau", "theta", "lambda", "sparsity", "worst L1"],
    );
    for card in suite(scale) {
        let cfg = card.attn_config();
        let samples: Vec<CalibSample> = (0..n_samples)
            .map(|i| {
                let mut rng = Pcg::new(7, i as u64 + 1);
                let s = match card.workload {
                    Workload::Lm(spec) => workloads::synthetic::generate(&spec, &mut rng),
                    Workload::Grid(spec) => workloads::video::generate_grid(&spec, &mut rng),
                };
                CalibSample { q: s.q, k: s.k, v: s.v }
            })
            .collect();
        let opts = TuneOptions { l1: card.l1, l2: card.l2, ..Default::default() };
        let res = tune_layer(&samples, &cfg, &opts);
        table.row(&[
            card.name.into(),
            card.seq_len().to_string(),
            format!("{}/{}", card.l1, card.l2),
            fnum(res.params.tau as f64, 2),
            fnum(res.params.theta as f64, 2),
            res.params.lambda.map(|l| format!("{l}")).unwrap_or_else(|| "-".into()),
            pct(res.sparsity),
            fnum(res.l1_error, 4),
        ]);
        if let Some(dir) = args.get("out-dir") {
            let cfg_out = ModelSpargeConfig::uniform(card.name, card.layers, res.params, card.l1, card.l2);
            let path = std::path::Path::new(dir).join(format!("{}.sparge.json", card.name));
            cfg_out.save(&path)?;
        }
    }
    table.print();
    println!("\ninvariant: worst L1 < l2 for every row; sparsity is maximized subject to it");
    Ok(())
}
