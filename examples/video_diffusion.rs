//! Video-diffusion example (Mochi/CogvideoX proxy): run the DiT denoise
//! loop through the runtime artifacts (dense vs sparge), then analyze
//! attention-level sparsity and the HilbertCurve permutation effect with
//! the Rust engine (paper §3.7, Fig. 1, Table 4).
//!
//!     cargo run --release --example video_diffusion
//!
//! Requires `make artifacts` (for part 1; part 2 is engine-only).

use sparge::attention::AttnEngine;
use sparge::attention::types::AttnConfig;
use sparge::coordinator::AttnMode;
use sparge::coordinator::EngineHandle;
use sparge::runtime::Manifest;
use sparge::sparge::hilbert::Permutation;
use sparge::sparge::metrics::{avg_block_similarity, psnr, rel_l1};
use sparge::sparge::SpargeParams;
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, pct, Table};
use sparge::workloads::video::{self, VideoSpec};

/// Geometry of the exported DiT artifact (aot.py: 2 x 24 x 24 grid).
const DIT_N: usize = 1152;
const DIT_D_IN: usize = 16;
const DIT_GRID: (usize, usize, usize) = (2, 24, 24);

fn main() -> anyhow::Result<()> {
    println!("=== [1/2] DiT denoise loop through the runtime (dense vs sparge artifacts) ===");
    let engine = EngineHandle::spawn(&Manifest::default_dir())?;
    let mut rng = Pcg::seeded(5);
    let steps = 8;

    let mut results = Vec::new();
    for mode in [AttnMode::Dense, AttnMode::Sparge] {
        // same initial noise for both runs
        let mut latents = rng.clone().gauss_vec(DIT_N * DIT_D_IN);
        // warm-up call: one-time XLA compilation happens here, not in the
        // timed loop (serving pays this once at startup)
        engine.dit_denoise(latents.clone(), DIT_N, DIT_D_IN, 1.0, mode)?;
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            let t = 1.0 - (s as f32 + 0.5) / steps as f32;
            let pred = engine.dit_denoise(latents.clone(), DIT_N, DIT_D_IN, t, mode)?;
            // simple Euler update toward the predicted direction
            for (x, p) in latents.iter_mut().zip(&pred) {
                *x -= p / steps as f32;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("  {}: {} denoise steps in {:.2}s ({:.0}ms/step)", mode.name(), steps, dt, dt / steps as f64 * 1e3);
        results.push((mode, latents, dt));
    }
    let dense_latents = sparge::tensor::Tensor::from_vec(&[DIT_N, DIT_D_IN], results[0].1.clone());
    let sparge_latents = sparge::tensor::Tensor::from_vec(&[DIT_N, DIT_D_IN], results[1].1.clone());
    println!(
        "  output fidelity sparge-vs-dense: rel-L1 {:.4}, PSNR {:.1} dB (paper: 'no video quality loss')",
        rel_l1(&sparge_latents, &dense_latents),
        psnr(&sparge_latents, &dense_latents)
    );

    println!("\n=== [2/2] attention-level analysis on the Mochi-proxy grid (Rust engine) ===");
    let spec = VideoSpec { t: DIT_GRID.0, h: DIT_GRID.1, w: DIT_GRID.2, d: 64, smooth: 0.96, signal: 11.0 };
    let mut rng = Pcg::seeded(11);
    let sample = video::generate_grid(&spec, &mut rng);
    let cfg = AttnConfig { bq: 128, bk: 64, causal: false, scale: None, cw: 4 };

    // paper Table 9 protocol: per-permutation pre-searched hyper-parameters
    // under the Mochi bounds l1=0.05, l2=0.06 (Sec. 3.6)
    let tune_opts = sparge::sparge::tune::TuneOptions {
        l1: 0.05,
        l2: 0.06,
        tau_grid: vec![0.98, 0.95, 0.9, 0.8],
        theta_grid: vec![0.0, 0.25, 0.45],
        lambda_grid: vec![-8.0, -5.0],
        quant: false,
    };

    let mut table = Table::new(
        "permutation effect (paper Table 4 shape; params tuned per row)",
        &["permutation", "Sim-q", "Sim-k", "rel-L1", "sparsity", "speedup"],
    );
    for perm in Permutation::all() {
        let ps = video::permute(&sample, &spec, perm, 3);
        let tuned = sparge::sparge::tune::tune_layer(
            &[sparge::sparge::tune::CalibSample { q: ps.q.clone(), k: ps.k.clone(), v: ps.v.clone() }],
            &cfg,
            &tune_opts,
        );
        let params: SpargeParams = tuned.params;
        let dense_engine = AttnEngine::dense(cfg);
        let dense = dense_engine.attention(&ps.q, &ps.k, &ps.v).out;
        let sparge_engine = AttnEngine::sparge(cfg, &params);
        let t0 = std::time::Instant::now();
        let res = sparge_engine.attention(&ps.q, &ps.k, &ps.v);
        let t_sparse = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let _ = dense_engine.attention(&ps.q, &ps.k, &ps.v);
        let t_dense = t1.elapsed().as_secs_f64();
        table.row(&[
            perm.name().into(),
            fnum(avg_block_similarity(&ps.q, cfg.bq), 3),
            fnum(avg_block_similarity(&ps.k, cfg.bk), 3),
            fnum(rel_l1(&res.out, &dense), 4),
            pct(res.stats.sparsity()),
            format!("{:.2}x", t_dense / t_sparse),
        ]);
    }
    table.print();
    println!("expected shape: HilbertCurve > Rowmajor/Timemajor > Random on Sim-k and sparsity;");
    println!("rel-L1 stays under l2=0.06 for every row (the tuner's constraint)");
    Ok(())
}
